"""Serve plane: continuous batching correctness (dense + paged KV),
chunked prefill, sampling, pause semantics, fleet placement, and the I10
token-determinism invariant."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_run_config
from repro.models.model import build_model
from repro.serve.engine import DrainResult, Request, ServeEngine
from repro.serve.fleet import EngineTenant, ServeFleet
from repro.serve.paged import (BlockAllocator, CacheExhausted,
                               RequestRejected)


@pytest.fixture(scope="module")
def setup():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


def naive_generate(model, params, prompt, n, max_len=48):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    cache, last = jax.jit(model.prefill)(params, batch)

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, max_len - x.shape[2]),
                               (0, 0), (0, 0)))
        return x
    cache = jax.tree_util.tree_map_with_path(pad, cache)
    toks = [int(jnp.argmax(last[0]))]
    pos = len(prompt) - 1
    dec = jax.jit(model.decode_step)
    for _ in range(n - 1):
        pos += 1
        lg, cache = dec(params, cache,
                        jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_engine_matches_naive_with_slot_recycling(setup):
    run, model, params = setup
    prompts = [np.arange(4) % 100, (np.arange(7) * 3) % 100,
               (np.arange(5) * 5 + 2) % 100]
    want = [naive_generate(model, params, p, 6) for p in prompts]
    eng = ServeEngine(run, params, slots=2, max_len=48)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.step() or eng.queue) and steps < 100:
        steps += 1
    for r, w in zip(reqs, want):
        assert r.out == w, (r.rid, r.out, w)
        assert r.done


def test_engine_pause_queues_requests(setup):
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    eng.pause()
    eng.submit(Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=3))
    assert eng.step() == 0 and len(eng.queue) == 1   # held while paused
    eng.unpause()
    steps = 0
    while (eng.step() or eng.queue) and steps < 50:
        steps += 1
    assert len(eng.queue) == 0


def test_run_until_idle_returns_finished_requests(setup):
    """Regression: run_until_idle used to always return [] — finished
    requests (decode-finished AND prefill-finished) must be collected."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    reqs = [Request(rid=0, prompt=np.arange(4) % 100, max_new_tokens=4),
            Request(rid=1, prompt=(np.arange(6) * 3) % 100,
                    max_new_tokens=1),       # finishes at prefill
            Request(rid=2, prompt=(np.arange(5) * 5 + 2) % 100,
                    max_new_tokens=3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_idle()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done for r in done)
    assert len(done[0].out) >= 1
    # a second call returns only newly-finished work, not stale requests
    eng.submit(Request(rid=3, prompt=np.arange(4) % 100, max_new_tokens=2))
    done2 = eng.run_until_idle()
    assert [r.rid for r in done2] == [3]


def test_engine_dirty_set_tracks_per_step_mutations(setup):
    """Serving tenants pre-copy params-free: params are clean after the
    first export; decode steps dirty only the cache/positions."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48)
    assert "params" in eng.dirty_keys()          # never exported yet
    st = eng.export_state()
    assert set(st) == {"params", "cache", "pos", "last_token"}
    assert st["params"] is params
    assert eng.dirty_keys() == set()
    eng.submit(Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=2))
    eng.run_until_idle()
    assert eng.dirty_keys() == {"cache", "pos", "last_token"}
    st2 = eng.export_state()
    assert st2["params"] is params               # identity-clean for memo


# ===========================================================================
# satellite bugfixes
# ===========================================================================
def test_overlong_request_rejected_typed_engine_survives(setup):
    """Regression: _admit used a bare ``assert`` (gone under python -O) —
    one over-long request killed the engine and its whole batch. Now it
    is rejected typed, marked done-with-error, and serving continues."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    bad = Request(rid=0, prompt=np.arange(40) % 100, max_new_tokens=20)
    good = Request(rid=1, prompt=np.arange(4) % 100, max_new_tokens=3)
    empty = Request(rid=2, prompt=np.zeros((0,), np.int32),
                    max_new_tokens=3)
    for r in (bad, good, empty):
        eng.submit(r)
    done = eng.run_until_idle()
    assert done.drained
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert bad.done and bad.error and "exceeds max_len" in bad.error
    assert empty.done and empty.error
    assert good.done and good.error is None and len(good.out) == 3


def test_idle_slot_masked_out_of_decode(setup):
    """Regression: inactive slots were decoded too — stale last_token/pos
    burned FLOPs and ``np.maximum(pos+1, 0)`` wrote KV at position 0 for
    EMPTY slots. Idle slots' cache bytes must stay bit-untouched."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=3, max_len=48)
    eng.submit(Request(rid=0, prompt=np.arange(5) % 100, max_new_tokens=4))
    eng.step()                                   # slot 0 active, 1/2 idle
    idle = jax.tree.map(
        lambda l: np.asarray(l[:, 1:]).copy(), eng._cache)
    while eng.step() or eng.queue:
        pass
    after = jax.tree.map(lambda l: np.asarray(l[:, 1:]), eng._cache)
    for a, b in zip(jax.tree.leaves(idle), jax.tree.leaves(after)):
        assert np.array_equal(a, b), "idle slot cache bytes changed"
    # and nothing was ever written at position 0 of an idle slot
    ksum = np.abs(np.asarray(
        jax.tree.leaves(after)[0])).sum()        # still all-zero KV
    assert ksum == 0.0
    assert eng.pos[1] == -1 and eng.pos[2] == -1


def test_run_until_idle_on_paused_engine_breaks_out(setup):
    """Regression: a paused engine with a non-empty queue used to spin all
    max_steps doing nothing, then report the early-finished requests as
    if the queue had drained. It must return immediately and surface the
    undrained state."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    eng.pause()
    eng.submit(Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=3))
    res = eng.run_until_idle(max_steps=10_000)
    assert isinstance(res, DrainResult)
    assert res == [] and res.drained is False     # work remains, none done
    assert len(eng.queue) == 1                    # queue intact
    eng.unpause()
    res2 = eng.run_until_idle()
    assert res2.drained and [r.rid for r in res2] == [0]


def test_prefill_finishing_requests_share_one_slot(setup):
    """Regression: a request finishing at prefill left its KV in the slot
    and consumed it for the rest of the admission pass. Both max_new=1
    requests must finish through ONE free slot in one pass, leaving the
    slot's cache untouched."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48)
    r0 = Request(rid=0, prompt=np.arange(4) % 100, max_new_tokens=1)
    r1 = Request(rid=1, prompt=(np.arange(6) * 3) % 100, max_new_tokens=1)
    eng.submit(r0)
    eng.submit(r1)
    eng.step()                                    # a single admission pass
    assert r0.done and r1.done and len(eng.queue) == 0
    assert eng.active[0] is None and eng.pos[0] == -1
    # nothing was ever inserted: the whole cache is pristine
    for leaf in jax.tree.leaves(eng._cache or {}):
        arr = np.asarray(leaf)
        assert np.all((arr == 0) | (arr == -1e30))


# ===========================================================================
# paged KV
# ===========================================================================
def test_block_allocator_mirrors_device_pool_semantics():
    a = BlockAllocator(num_pages=9, page_size=4)
    assert a.capacity == 8
    p0 = a.allocate(0, 3)
    p1 = a.allocate(1, 2)
    assert not set(p0) & set(p1) and 0 not in p0 + p1
    a.check_invariants()
    with pytest.raises(CacheExhausted):
        a.allocate(2, 4)                          # only 3 free
    with pytest.raises(RequestRejected):
        a.allocate(3, 9)                          # > capacity: permanent
    a.free(0)
    holes = a.allocate(4, 2)                      # reuses freed low ids
    assert holes == [1, 2]
    a.check_invariants()
    a.free(1)
    moves = a.defragment()                        # compact to the front
    a.check_invariants()
    assert sorted(q for ps in a.owners().values() for q in ps) == [1, 2]
    assert all(new < old for old, new in moves.items())


def test_paged_engine_matches_dense_and_naive(setup):
    run, model, params = setup
    prompts = [np.arange(4) % 100, (np.arange(7) * 3) % 100,
               (np.arange(5) * 5 + 2) % 100, (np.arange(9) * 11 + 1) % 100]
    want = [naive_generate(model, params, p, 6) for p in prompts]

    def serve(**kw):
        eng = ServeEngine(run, params, slots=2, max_len=48, **kw)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        res = eng.run_until_idle()
        assert res.drained and all(r.done for r in reqs)
        return [r.out for r in reqs]

    assert serve(paged=True, page_size=8) == want
    assert serve(prefill_chunk=3) == want
    assert serve(paged=True, page_size=8, prefill_chunk=3) == want


def test_paged_pool_exhaustion_backs_off_then_serves(setup):
    """A pool too small for all requests at once serves them anyway —
    admission backs off (requests stay queued) until pages free up."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=4, max_len=48, paged=True,
                      page_size=8, num_pages=4)     # 3 usable pages
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % 100,
                    max_new_tokens=4) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    res = eng.run_until_idle()
    assert res.drained and all(r.done and not r.error for r in reqs)
    assert eng.alloc.num_free == eng.alloc.capacity  # all pages returned


def test_paged_defragment_preserves_decode(setup):
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=4)
    reqs = [Request(rid=i, prompt=(np.arange(5) * (i + 2)) % 100,
                    max_new_tokens=8) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):                             # mid-flight
        eng.step()
    eng.defragment()
    eng.alloc.check_invariants()
    res = eng.run_until_idle()
    assert res.drained and all(r.done for r in reqs)
    # outputs equal an engine that never defragmented
    eng2 = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                       page_size=4)
    reqs2 = [Request(rid=i, prompt=(np.arange(5) * (i + 2)) % 100,
                     max_new_tokens=8) for i in range(3)]
    for r in reqs2:
        eng2.submit(r)
    eng2.run_until_idle()
    assert [r.out for r in reqs] == [r.out for r in reqs2]


# ===========================================================================
# sampling
# ===========================================================================
def test_sampling_deterministic_and_temperature_zero_is_greedy(setup):
    run, model, params = setup

    def serve(temp, top_k, seed=7):
        eng = ServeEngine(run, params, slots=2, max_len=48)
        reqs = [Request(rid=i, prompt=(np.arange(4) * (i + 1)) % 100,
                        max_new_tokens=5, temperature=temp, top_k=top_k,
                        seed=seed) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        return [r.out for r in reqs]

    greedy = serve(0.0, 0)
    assert greedy == serve(0.0, 0)
    sampled = serve(0.9, 8)
    assert sampled == serve(0.9, 8)               # counter-seeded RNG
    assert sampled != serve(0.9, 8, seed=8)       # stream actually varies


def test_mid_run_pause_roundtrip_token_identical(setup):
    """The real-engine I10: a pause/export/import round-trip mid-decode
    (sampled!) must not change any request's tokens."""
    run, model, params = setup
    prompts = [np.arange(4) % 100, (np.arange(7) * 3) % 100]

    def serve(pause_at=None):
        eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                          page_size=8)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6,
                        temperature=0.8, top_k=16)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        steps = 0
        while (eng.step() or eng.queue) and steps < 100:
            steps += 1
            if pause_at is not None and steps == pause_at:
                eng.pause()
                st = eng.export_state()
                eng._cache = None
                eng.import_state(st)
                eng.unpause()
        return [r.out for r in reqs]

    assert serve() == serve(pause_at=2)


# ===========================================================================
# fleet: engines as tenants under the SVFF manager
# ===========================================================================
def _fleet(run, params, policy, **kw):
    return ServeFleet(run, params, num_engines=2, num_devices=4,
                      policy=policy, slots=2, max_len=48, paged=True,
                      page_size=8, workdir=tempfile.mkdtemp(), **kw)


@pytest.mark.parametrize("policy", ["first_fit", "best_fit", "fair_share"])
def test_fleet_serves_through_pause_live_and_migrate(setup, policy):
    run, model, params = setup
    fleet = _fleet(run, params, policy)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, 500,
                                               int(rng.integers(3, 8))),
                    max_new_tokens=4) for i in range(6)]
    for r in reqs[:4]:
        fleet.submit(r)
    for _ in range(2):
        fleet.step()
    t = fleet.pause_live("serve0", rounds=2)      # fires mid-traffic
    assert t.background                           # pre-copy really ran
    for r in reqs[4:]:
        fleet.submit(r)                           # arrivals while paused
    fleet.unpause("serve0")
    fleet.migrate("serve1")
    done = fleet.drain()
    assert sorted(r.rid for r in done) == list(range(6))
    assert all(r.done and not r.error for r in reqs)
    assert fleet.mgr.query()["journal_pending"] == 0


def test_chunked_prefill_works_with_pallas_backend(setup):
    """Regression: attention()'s kernel-dispatch guard bool()'d the traced
    chunk offset (TracerBoolConversionError) under kernel_backend=pallas."""
    run, model, params = setup
    prun = run.replace(kernel_backend="pallas")
    eng = ServeEngine(prun, params, slots=1, max_len=48, prefill_chunk=3)
    req = Request(rid=0, prompt=np.arange(7) % 100, max_new_tokens=2)
    eng.submit(req)
    res = eng.run_until_idle()
    assert res.drained and req.done and len(req.out) == 2


def test_fleet_drain_surfaces_stranded_paused_engine(setup):
    """Regression: drain() on a fleet with a still-paused engine reported
    a partial drain as complete (the bug the run_until_idle satellite
    fixed, reintroduced one level up)."""
    run, model, params = setup
    fleet = _fleet(run, params, "first_fit")
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % 100,
                    max_new_tokens=6) for i in range(4)]
    for r in reqs:
        fleet.submit(r)
    for _ in range(2):
        fleet.step()
    fleet.pause_live("serve0", rounds=1)          # ... and never unpause
    res = fleet.drain()
    assert res.drained is False                   # stranded work surfaced
    assert any(not r.done for r in reqs)
    fleet.unpause("serve0")
    res2 = fleet.drain()
    assert res2.drained is True
    assert all(r.done for r in reqs)


def test_pause_mid_chunked_prefill_requeues_jobs_token_identical(setup):
    """Regression: a pause landing while chunked-prefill jobs are in
    flight must not lose them — suspend re-queues the jobs (no tokens
    emitted yet, prefill deterministic), frees their pages, and the
    post-resume outputs equal an undisturbed run."""
    run, model, params = setup

    def serve(pause_mid_prefill):
        fleet = ServeFleet(run, params, num_engines=1, num_devices=2,
                           slots=2, max_len=48, paged=True, page_size=8,
                           prefill_chunk=3, workdir=tempfile.mkdtemp())
        eng = fleet.tenants["serve0"].engine
        reqs = [Request(rid=i, prompt=(np.arange(8 + i) * 5) % 100,
                        max_new_tokens=4) for i in range(3)]
        for r in reqs:
            fleet.submit(r)
        fleet.step()                      # jobs created, prompts > chunk
        if pause_mid_prefill:
            assert eng._jobs              # a prefill really is in flight
            fleet.pause_live("serve0", rounds=1)
            assert not eng._jobs          # re-queued, not stranded
            assert eng.alloc.check_invariants() is None
            fleet.unpause("serve0")
        res = fleet.drain()
        assert res.drained and all(r.done and not r.error for r in reqs)
        return [r.out for r in reqs]

    assert serve(False) == serve(True)


def test_fleet_slo_rejection_then_retry_completes(setup):
    """Regression: submit used to set ``req.done = True`` and
    ``req.error`` on the SLO-rejection path BEFORE raising, so a caller
    retrying the same Request after backoff submitted an object every
    engine treated as already finished (its loop dropped it on the first
    step, done-with-stale-error). Rejection must be side-effect-free on
    the request — tracked fleet-side only — and the retry must serve
    normally."""
    run, model, params = setup
    fleet = ServeFleet(run, params, num_engines=1, num_devices=2, slots=1,
                       max_len=48, slo_max_load=1,
                       workdir=tempfile.mkdtemp())
    fleet.submit(Request(rid=0, prompt=np.arange(4), max_new_tokens=2))
    over = Request(rid=1, prompt=np.arange(4), max_new_tokens=2)
    with pytest.raises(RequestRejected):
        fleet.submit(over)
    # the request object is UNTOUCHED: the caller owns retry policy
    assert over.done is False and over.error is None and over.out == []
    # the rejection is visible fleet-side instead
    assert len(fleet.rejections) == 1
    assert fleet.rejections[0]["rid"] == 1
    assert fleet.telemetry.rejected["serve0"] == 1
    done = fleet.drain()
    assert sorted(r.rid for r in done) == [0]     # only real completions
    fleet.submit(over)                            # retry after backoff
    done2 = fleet.drain()
    assert [r.rid for r in done2] == [1]
    assert over.done and over.error is None and len(over.out) == 2


def test_fleet_tie_break_is_creation_order_not_lexicographic(setup):
    """Regression: load ties broke on the tid STRING, so a >= 10 engine
    fleet placed round-robin as serve0, serve1, serve10, serve11,
    serve2, ... — placement must follow engine creation index (this
    matters once the autoscaler spawns tenants dynamically)."""
    run, model, params = setup
    fleet = ServeFleet(run, params, num_engines=12, num_devices=12,
                       slots=1, max_len=48, workdir=tempfile.mkdtemp())
    placements = [fleet.submit(Request(rid=i, prompt=np.arange(4) % 50,
                                       max_new_tokens=1))
                  for i in range(12)]
    assert placements == [f"serve{i}" for i in range(12)]


def test_fleet_placement_follows_policy_heterogeneous_pool(setup):
    """fair_share/best_fit placement of serving tenants over a
    heterogeneous VF table (sizes 2,1,4 + 1 occupied -> share 4)."""
    from repro.core import SVFFManager
    from tests.test_scheduler import make_pool
    run, model, params = setup

    def attach_one(policy):
        pool = make_pool()                         # sizes (2, 1, 4) + occ
        mgr = SVFFManager(pool, workdir=tempfile.mkdtemp(),
                          scheduler=policy)
        eng = ServeEngine(run, jax.tree.map(jnp.array, params), slots=1,
                          max_len=48)
        tn = EngineTenant("serveX", eng, placement=policy)
        mgr.attach(tn)
        return len(pool.vfs[tn.vf_id].devices)

    assert attach_one("first_fit") == 2            # PF table order
    assert attach_one("best_fit") == 1             # smallest sufficient
    assert attach_one("fair_share") == 4           # closest to share


def test_make_scheduler_instance_cached_across_managers():
    from repro.core import DevicePool, SVFFManager, make_scheduler
    a = SVFFManager(DevicePool(devices=("x0",)),
                    workdir=tempfile.mkdtemp(), scheduler="best_fit")
    b = SVFFManager(DevicePool(devices=("x1",)),
                    workdir=tempfile.mkdtemp(), scheduler="best_fit")
    assert a.scheduler is b.scheduler              # stateless + cached
    assert a.scheduler is make_scheduler("best_fit")


# ===========================================================================
# I10 in the scenario simulator
# ===========================================================================
def test_sim_i10_regression_seeds():
    """Checked-in regression seeds: serve traffic + pause/pause_live/
    migrate interleavings stay token-deterministic (I10), replay-stable,
    across all three placement policies."""
    from repro.sim import ScenarioConfig, ScenarioRunner
    for policy in ("first_fit", "best_fit", "fair_share"):
        cfg = ScenarioConfig(seed=3, policy=policy, serve_rate=0.35,
                             num_ops=30)
        res = ScenarioRunner(cfg).run()
        assert res.fingerprint() == ScenarioRunner(cfg).run().fingerprint()
        kinds = {r.op.kind for r in res.ops}
        assert "serve_submit" in kinds


def test_sim_serve_tenant_oracle_catches_corruption():
    """I10 has teeth: flipping one byte of live paged KV diverges the
    token stream from the no-reconfiguration oracle."""
    from repro.sim import SimServeTenant

    class _VF:
        mesh_shape = (1, 1)
        mesh_axes = ("data", "model")
        devices = ("d0",)
        vf_id = "vf1"
        emulated: dict = {}

    tn = SimServeTenant("sv0", seed=3)
    tn.bind(_VF())
    tn.submit_burst(3)
    tn.run_steps(2)
    req = next(r for r in tn.requests if r.out and not r.done)
    tn.pages[tn.tables[0][0], 0] += 1              # corrupt one cell
    tn.run_steps(1)
    want = tn.expected_output(tn.seed, req.rid)
    assert list(req.out) != want[:len(req.out)]


@pytest.mark.slow
def test_sim_i10_sweep_all_policies():
    from repro.sim import ScenarioConfig, ScenarioRunner
    for policy in ("first_fit", "best_fit", "fair_share"):
        for seed in range(10):
            ScenarioRunner(ScenarioConfig(
                seed=seed, policy=policy, serve_rate=0.35,
                num_ops=28)).run()


def test_engine_eos_stops_early(setup):
    run, model, params = setup
    # discover the first greedy token, then use it as the EOS id
    probe = Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=2)
    eng = ServeEngine(run, params, slots=1, max_len=48)
    eng.submit(probe)
    while eng.step() or eng.queue:
        pass
    eos = probe.out[0]
    req = Request(rid=1, prompt=np.arange(4) % 50, max_new_tokens=10,
                  eos_id=eos)
    eng2 = ServeEngine(run, params, slots=1, max_len=48)
    eng2.submit(req)
    while eng2.step() or eng2.queue:
        pass
    assert req.done and len(req.out) == 1 and req.out[0] == eos
