"""End-to-end behaviour tests for the full system: the training driver
(incl. crash/restart fault tolerance), the serving driver, and a
reduced-mesh dry-run through the real dryrun entry point."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(argv, timeout=900, extra_env=None):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    if extra_env:
        env.update(extra_env)
    return subprocess.run([sys.executable] + argv, capture_output=True,
                          text=True, env=env, timeout=timeout)


@pytest.mark.slow
def test_train_driver_learns(tmp_path):
    out = run_py(["-m", "repro.launch.train", "--arch", "qwen3-0.6b",
                  "--smoke", "--steps", "30", "--batch", "8", "--seq", "64",
                  "--lr", "3e-3", "--warmup", "5", "--workdir",
                  str(tmp_path), "--checkpoint-every", "10"])
    assert out.returncode == 0, out.stderr[-2000:]
    lines = [json.loads(l) for l in
             open(tmp_path / "metrics.jsonl").read().splitlines()]
    assert lines[-1]["step"] == 30
    assert lines[-1]["loss"] < lines[0]["loss"] - 0.3, (
        lines[0]["loss"], lines[-1]["loss"])
    # checkpoints exist
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ckpt"))


@pytest.mark.slow
def test_train_crash_restart_bit_identical(tmp_path):
    """Kill the driver mid-run; --resume must produce the same final loss
    as an uninterrupted run (determinism + crash consistency)."""
    common = ["-m", "repro.launch.train", "--arch", "qwen3-0.6b", "--smoke",
              "--steps", "20", "--batch", "4", "--seq", "32", "--lr", "1e-2",
              "--warmup", "2", "--checkpoint-every", "5"]
    ref = run_py(common + ["--workdir", str(tmp_path / "a")])
    assert ref.returncode == 0, ref.stderr[-2000:]
    ref_last = json.loads(ref.stdout.strip().splitlines()[-1])

    crash = run_py(common + ["--workdir", str(tmp_path / "b"),
                             "--crash-at", "10"])
    assert crash.returncode == 17          # simulated hard crash
    resume = run_py(common + ["--workdir", str(tmp_path / "b"), "--resume"])
    assert resume.returncode == 0, resume.stderr[-2000:]
    res_last = json.loads(resume.stdout.strip().splitlines()[-1])
    assert res_last["loss"] == pytest.approx(ref_last["loss"], abs=1e-5)


@pytest.mark.slow
def test_serve_driver_completes():
    out = run_py(["-m", "repro.launch.serve", "--arch", "qwen3-0.6b",
                  "--smoke", "--requests", "5", "--slots", "2",
                  "--new-tokens", "4", "--max-len", "32"])
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["completed"] == 5
    assert res["generated_tokens"] == 20


@pytest.mark.slow
def test_dryrun_entrypoint_reduced_mesh(tmp_path):
    """The real dryrun.py cell path on a reduced (8-device) mesh: lower +
    compile + roofline JSON for one cell. (The full 512-device sweep's
    committed results are validated by test_full_sweep_results_complete.)"""
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "import repro.configs.base as B\n"
        "import repro.launch.mesh as M\n"
        "import jax\n"
        "B.SINGLE_POD_MESH = B.MeshConfig((4, 2), ('data', 'model'))\n"
        "M.make_production_mesh = "
        "lambda *, multi_pod=False: jax.make_mesh((4, 2), "
        "('data', 'model'))\n"
        "from repro.launch.dryrun import run_cell\n"
        f"r = run_cell('qwen3-0.6b', 'train_4k', False, "
        f"out_dir='{tmp_path}', force=True)\n"
        "assert r['status'] == 'ok', r.get('error')\n"
        "print(r['status'], r['roofline']['bound'])\n"
    )
    out = run_py(["-c", prog])
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip().splitlines()[-1].startswith("ok")


@pytest.mark.sweep
def test_full_sweep_results_complete():
    """The committed dry-run sweep must cover all 40 cells x 2 meshes with
    no errors (skips only where DESIGN.md §4 documents them). Gated at
    COLLECTION time (conftest deselects ``sweep`` tests in checkouts
    without the committed results; ``SVFF_FULL_SWEEP=1`` forces them on)
    so the suite reports a deselection, never a silent runtime skip."""
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    assert os.path.isdir(d), f"no committed sweep results at {d}"
    statuses = {}
    for fn in os.listdir(d):
        if not fn.endswith(".json"):
            continue
        mesh_part = fn.rsplit("__", 1)[-1].replace(".json", "")
        if mesh_part not in ("single", "multi"):
            continue                     # tagged perf-iteration cells
        r = json.load(open(os.path.join(d, fn)))
        statuses[fn] = r["status"]
    assert len(statuses) == 80
    errors = {k: v for k, v in statuses.items() if v == "error"}
    assert not errors, errors
    skips = [k for k, v in statuses.items() if v == "skipped"]
    assert all("long_500k" in k for k in skips)
    assert len(skips) == 16                  # 8 full-attention archs x 2
