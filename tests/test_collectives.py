"""Compressed gradient all-reduce: exactness of the reduce phase, bounded
quantization error of the gather phase (subprocess: 8-device mesh)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_compressed_allreduce_matches_exact_mean():
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.runtime.collectives import compressed_grad_allreduce

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
# per-replica gradients with very different magnitudes per leaf
grads = {
    "big": jnp.asarray(rng.standard_normal((8, 16, 512)) * 3.0,
                       jnp.float32),
    "scaled": jnp.asarray(rng.standard_normal((8, 4, 1024)) * 1e-4,
                          jnp.float32),
    "tiny": jnp.asarray(rng.standard_normal((8, 7)), jnp.float32),
}
want = {k: np.asarray(v).mean(0) for k, v in grads.items()}
got = jax.jit(lambda g: compressed_grad_allreduce(g, mesh))(grads)
rel = {}
for k in grads:
    g = np.asarray(got[k])
    w = want[k]
    rel[k] = float(np.abs(g - w).max() / (np.abs(w).max() + 1e-12))
print(json.dumps(rel))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rel = json.loads(out.stdout.strip().splitlines()[-1])
    assert rel["tiny"] < 1e-6                 # exact pmean path
    assert rel["big"] < 0.02                  # one int8 quantization step
    assert rel["scaled"] < 0.02               # scale-invariant (blockwise)


@pytest.mark.slow
def test_compressed_allreduce_error_is_one_quantization_step():
    """Regression pin: the gather phase quantizes each value exactly ONCE
    (the reduce-scatter stays fp32-exact), so the relative error of the
    compressed leaves is bounded by half an int8 step of the block max —
    0.5/127 ~= 0.00394 — and does NOT accumulate over the 8 devices (a
    regression to naive quantized-ring accumulation would be ~8x)."""
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.runtime.collectives import compressed_grad_allreduce

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(7)
rel = {}
for name, scale in (("unit", 1.0), ("small", 1e-5), ("large", 1e4)):
    g = {"x": jnp.asarray(rng.standard_normal((8, 16, 512)) * scale,
                          jnp.float32)}
    want = np.asarray(g["x"]).mean(0)
    got = np.asarray(jax.jit(
        lambda t: compressed_grad_allreduce(t, mesh))(g)["x"])
    rel[name] = float(np.abs(got - want).max()
                      / (np.abs(want).max() + 1e-12))
print(json.dumps(rel))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rel = json.loads(out.stdout.strip().splitlines()[-1])
    bound = 0.5 / 127 * 1.15        # half-step + fp/blockmax headroom
    for name, r in rel.items():
        assert r < bound, (name, r, bound)
