"""Scheduler subsystem: placement policies, admission control, and the
manager/RunConfig policy-selection knob."""
import types

import pytest

from repro.configs import make_run_config
from repro.core import (AdmissionError, DevicePool, PlacementRequest,
                        POLICY_NAMES, SVFFManager, StagingEngine,
                        VFState, VirtualFunction, make_scheduler)
from repro.sim import SimTenant


def make_pool(sizes=(2, 1, 4), occupied_extra=True):
    """Heterogeneous detached VFs (sizes in PF table order) + optionally
    one occupied VF so fair-share sees a non-trivial share."""
    n = sum(sizes) + (1 if occupied_extra else 0)
    devices = tuple(f"d{i}" for i in range(n))
    pool = DevicePool(devices=devices)
    pool._rescanned = True
    idx = 0
    for i, s in enumerate(sizes):
        vf = VirtualFunction(vf_id=f"0000:03:00.{i + 1}")
        vf.assign_devices(devices[idx:idx + s], (s, 1))
        idx += s
        pool.vfs[vf.vf_id] = vf
    if occupied_extra:
        vf = VirtualFunction(vf_id="0000:03:00.9")
        vf.assign_devices(devices[idx:idx + 1], (1, 1))
        vf.owner = "occupant"
        vf.transition(VFState.ATTACHED)
        pool.vfs[vf.vf_id] = vf
    return pool


REQ = PlacementRequest(tenant_id="vmX")


# sizes (2, 1, 4), pool of 8 devices, 1 occupied tenant -> share = 4
def test_first_fit_takes_table_order():
    vf = make_scheduler("first_fit").select(make_pool(), {}, REQ)
    assert vf.vf_id == "0000:03:00.1"              # size 2, first


def test_best_fit_takes_smallest_sufficient():
    vf = make_scheduler("best_fit").select(make_pool(), {}, REQ)
    assert len(vf.devices) == 1                    # the size-1 slice
    req4 = PlacementRequest(tenant_id="vmX", min_devices=3)
    vf = make_scheduler("best_fit").select(make_pool(), {}, req4)
    assert len(vf.devices) == 4


def test_fair_share_takes_closest_to_share():
    vf = make_scheduler("fair_share").select(make_pool(), {}, REQ)
    assert len(vf.devices) == 4                    # share = 8/(1+1) = 4


def test_policies_are_deterministic_and_distinct():
    picks = {p: make_scheduler(p).select(make_pool(), {}, REQ).vf_id
             for p in POLICY_NAMES}
    assert picks == {p: make_scheduler(p).select(make_pool(), {}, REQ).vf_id
                     for p in POLICY_NAMES}
    assert len(set(picks.values())) == 3           # all three differ here


def test_admission_rejects_without_capacity():
    pool = make_pool(sizes=(1,), occupied_extra=False)
    sched = make_scheduler("first_fit")
    with pytest.raises(AdmissionError):
        sched.select(pool, {}, PlacementRequest("vmX", min_devices=2))
    pool.vfs["0000:03:00.1"].owner = "other"
    pool.vfs["0000:03:00.1"].transition(VFState.ATTACHED)
    with pytest.raises(AdmissionError):
        sched.select(pool, {}, REQ)


def test_admission_rejects_double_attach():
    tn = types.SimpleNamespace(status="running", vf_id="0000:03:00.1")
    with pytest.raises(AdmissionError, match="already holds"):
        make_scheduler("first_fit").select(
            make_pool(), {"vmX": tn}, REQ)


def test_unknown_policy_raises():
    with pytest.raises(KeyError):
        make_scheduler("tightest_fit")


# ---------------------------------------------------------------------------
# manager integration + RunConfig knob
# ---------------------------------------------------------------------------
def test_manager_scheduler_knob(tmp_path):
    pool = make_pool()
    mgr = SVFFManager(pool, workdir=str(tmp_path), scheduler="best_fit",
                      staging=StagingEngine(num_queues=1))
    tn = SimTenant("vm0", seed=0)
    mgr.attach(tn)
    assert len(pool.find(tn.vf_id).devices) == 1   # best-fit placement
    assert mgr.query()["scheduler"] == {"policy": "best_fit"}


def test_manager_resolves_policy_from_tenant_run(tmp_path):
    """scheduler=None -> the per-tenant RunConfig.placement knob wins."""
    pool = make_pool()
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1))
    fair = SimTenant("vm0", seed=0, placement="fair_share")
    mgr.attach(fair)
    assert len(pool.find(fair.vf_id).devices) == 4
    first = SimTenant("vm1", seed=1, placement="first_fit")
    mgr.attach(first)
    assert pool.find(first.vf_id).vf_id == "0000:03:00.1"


def test_runconfig_placement_field():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    assert run.placement == "first_fit"
    assert run.replace(placement="fair_share").placement == "fair_share"
