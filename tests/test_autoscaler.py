"""Elastic SLO control plane: policy-loop unit behaviour (hysteresis,
cooldown, justification), fleet-level scale-out/in/rebalance through the
journaled manager ops, and the sim's autoscale op with invariant I11."""
import tempfile

import numpy as np
import pytest

from repro.core.autoscaler import (Autoscaler, AutoscaleAction,
                                   AutoscaleConfig, EngineStats,
                                   TelemetrySnapshot, justify_action)
from repro.sim import (InvariantViolation, ScenarioConfig, ScenarioRunner,
                       check_autoscale, generate_scenario)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


def snap(loads, *, epoch=0, slo=8, free_vfs=1, grow=0, queued=None,
         jobs=None, widths=None, wmax=None, bubbles=None):
    """Synthetic telemetry: engine i running at loads[i]; widths/wmax/
    bubbles optionally give each engine a pipeline-gang shape."""
    queued = queued if queued is not None else loads
    jobs = jobs or [0] * len(loads)
    widths = widths or [1] * len(loads)
    wmax = wmax or widths
    bubbles = bubbles or [0.0] * len(loads)
    return TelemetrySnapshot(
        epoch=epoch, slo_max_load=slo,
        engines=tuple(
            EngineStats(tid=f"e{i}", index=i, status="running",
                        load=loads[i], queue_depth=queued[i],
                        prefill_jobs=jobs[i], stage_width=widths[i],
                        stage_width_max=wmax[i], bubble_frac=bubbles[i])
            for i in range(len(loads))),
        free_vfs=free_vfs, grow_budget=grow)


# ===========================================================================
# policy loop
# ===========================================================================
def test_scale_out_needs_hot_engine_and_capacity():
    a = Autoscaler(AutoscaleConfig(hysteresis=1, cooldown=0))
    assert a.observe(snap([2])) is None            # below threshold
    act = a.observe(snap([8]))
    assert act is not None and act.kind == "scale_out"
    assert justify_action(act, a.cfg) is None
    # no capacity -> no action even when hot
    b = Autoscaler(AutoscaleConfig(hysteresis=1, cooldown=0))
    assert b.observe(snap([8], free_vfs=0, grow=0)) is None


def test_hysteresis_requires_sustained_condition():
    a = Autoscaler(AutoscaleConfig(hysteresis=3, cooldown=0))
    assert a.observe(snap([8])) is None            # streak 1
    assert a.observe(snap([8])) is None            # streak 2
    assert a.observe(snap([0])) is None            # streak reset
    assert a.observe(snap([8])) is None
    assert a.observe(snap([8])) is None
    assert a.observe(snap([8])).kind == "scale_out"


def test_cooldown_suppresses_flapping_on_oscillating_load():
    """Load oscillating hot/idle every epoch must not produce an action
    per epoch: after each action the loop is silent for ``cooldown``
    epochs, and scale_in additionally needs an idle STREAK, which the
    oscillation keeps resetting."""
    cfg = AutoscaleConfig(hysteresis=1, cooldown=4, min_engines=1)
    a = Autoscaler(cfg)
    actions = []
    for epoch in range(32):
        hot = epoch % 2 == 0
        s = snap([9 if hot else 0, 1], epoch=epoch)
        act = a.observe(s)
        if act:
            actions.append((epoch, act.kind))
    # one action per (1 + cooldown) epochs at most
    assert len(actions) <= 32 // (1 + cfg.cooldown) + 1
    for (e1, _), (e2, _) in zip(actions, actions[1:]):
        assert e2 - e1 > cfg.cooldown
    # steady load produces NO actions at all once balanced
    b = Autoscaler(cfg)
    assert all(b.observe(snap([3, 3], epoch=i)) is None
               for i in range(10))


def test_scale_in_only_when_idle_and_above_floor():
    cfg = AutoscaleConfig(hysteresis=2, cooldown=0, min_engines=1)
    a = Autoscaler(cfg)
    assert a.observe(snap([0, 0])) is None         # idle streak 1
    act = a.observe(snap([0, 0]))                  # idle streak 2
    assert act is not None and act.kind == "scale_in"
    assert act.victim == "e1"                      # newest idle engine
    assert justify_action(act, cfg) is None
    # at the floor: never
    b = Autoscaler(AutoscaleConfig(hysteresis=1, cooldown=0,
                                   min_engines=1))
    assert b.observe(snap([0])) is None
    assert b.observe(snap([0])) is None


def test_rebalance_preferred_over_scale_out_when_cold_engine_exists():
    cfg = AutoscaleConfig(hysteresis=1, cooldown=0, rebalance_gap=4)
    act = Autoscaler(cfg).observe(snap([9, 0]))
    assert act.kind == "rebalance"
    assert act.victim == "e0" and act.target == "e1"
    assert justify_action(act, cfg) is None


def test_justification_catches_unjustified_actions():
    """I11 has teeth: actions forged against a snapshot that does not
    support them are named violations."""
    cfg = AutoscaleConfig()
    cold = snap([0, 0])
    for bogus, needle in (
            (AutoscaleAction("scale_out", cold), "no engine at load"),
            (AutoscaleAction("scale_in", snap([5, 5]), victim="e1"),
             "busy engine"),
            (AutoscaleAction("rebalance", snap([3, 2]), victim="e0",
                             target="e1"), "without imbalance"),
            (AutoscaleAction("warp", cold), "unknown action")):
        err = justify_action(bogus, cfg)
        assert err is not None and needle in err
        with pytest.raises(InvariantViolation, match="I11"):
            check_autoscale(bogus, cfg)


# ===========================================================================
# the width dimension: grow/shrink reshape in the policy loop
# ===========================================================================
def test_grow_reshape_only_when_engines_maxed():
    """With engine-count headroom a hot fleet scales OUT; only once
    ``max_engines`` is hit does the planner widen the hottest gang —
    and then only if a free VF exists and the gang has template room."""
    cfg = AutoscaleConfig(hysteresis=1, cooldown=0, max_engines=1)
    act = Autoscaler(cfg).observe(
        snap([9], widths=[2], wmax=[4], free_vfs=1))
    assert act is not None and act.kind == "reshape"
    assert act.victim == "e0" and act.width == 3
    assert justify_action(act, cfg) is None
    # engine headroom -> scale_out wins over widening
    roomy = AutoscaleConfig(hysteresis=1, cooldown=0, max_engines=4)
    act = Autoscaler(roomy).observe(
        snap([9], widths=[2], wmax=[4], free_vfs=1))
    assert act is not None and act.kind == "scale_out"
    # no free VF -> nothing to widen with
    assert Autoscaler(cfg).observe(
        snap([9], widths=[2], wmax=[4], free_vfs=0)) is None
    # at the template ceiling -> no grow either
    assert Autoscaler(cfg).observe(
        snap([9], widths=[4], wmax=[4], free_vfs=1)) is None


def test_shrink_reshape_on_measured_bubble():
    """A gang whose measured schedule bubble crosses ``reshape_bubble``
    is narrowed before any engine is parked; a busy low-bubble gang is
    left alone."""
    cfg = AutoscaleConfig(hysteresis=1, cooldown=0, min_engines=1)
    act = Autoscaler(cfg).observe(
        snap([2, 3], widths=[3, 1], wmax=[4, 1], bubbles=[0.7, 0.0]))
    assert act is not None and act.kind == "reshape"
    assert act.victim == "e0" and act.width == 2
    assert justify_action(act, cfg) is None
    assert Autoscaler(cfg).observe(
        snap([2, 3], widths=[3, 1], wmax=[4, 1],
             bubbles=[0.2, 0.0])) is None


def test_justification_catches_unjustified_reshapes():
    """I11 covers the width dimension: reshape actions the snapshot does
    not support are named violations."""
    cfg = AutoscaleConfig()
    for bogus, needle in (
            (AutoscaleAction("reshape", snap([9]), victim="e9", width=2),
             "not running"),
            (AutoscaleAction("reshape", snap([9], widths=[2], wmax=[4]),
                             victim="e0", width=2), "to width 2 from 2"),
            (AutoscaleAction("reshape", snap([9], widths=[2], wmax=[2]),
                             victim="e0", width=3), "template ceiling"),
            (AutoscaleAction("reshape", snap([1], widths=[2], wmax=[4]),
                             victim="e0", width=3), "hot threshold"),
            (AutoscaleAction("reshape",
                             snap([9], widths=[2], wmax=[4], free_vfs=0),
                             victim="e0", width=3), "free VF"),
            (AutoscaleAction("reshape",
                             snap([5], widths=[2], wmax=[4],
                                  bubbles=[0.1]),
                             victim="e0", width=1), "busy")):
        err = justify_action(bogus, cfg)
        assert err is not None and needle in err
        with pytest.raises(InvariantViolation, match="I11"):
            check_autoscale(bogus, cfg)


# ===========================================================================
# real fleet: scale-out / scale-in / rebalance through the manager
# ===========================================================================
def test_fleet_vf_cap_follows_device_budget_and_scales_out(setup):
    """Regression: ``DevicePool(max_vfs=max(num_engines, 1))`` froze the
    VF count at the initial engine count, so ANY reconfiguration to more
    VFs was silently impossible. The cap must be the device budget, and
    scale-out past the initial fleet size must serve traffic on the new
    engine (grow path: the full reconf cycle carves one more VF)."""
    from repro.serve import Request, ServeFleet
    run, model, params = setup
    fleet = ServeFleet(run, params, num_engines=1, num_devices=4, slots=2,
                       max_len=48, workdir=tempfile.mkdtemp())
    assert fleet.pool.max_vfs == 4                  # device budget, not 1
    tid = fleet.scale_out()                         # past the initial size
    assert tid == "serve1"
    assert sum(1 for tn in fleet.tenants.values()
               if tn.status == "running") == 2
    assert len(fleet.pool.vfs) == 2
    reqs = [Request(rid=i, prompt=np.arange(4) % 50, max_new_tokens=2)
            for i in range(4)]
    placed = {fleet.submit(r) for r in reqs}
    assert placed == {"serve0", "serve1"}           # both engines serve
    res = fleet.drain()
    assert res.drained and all(r.done and not r.error for r in reqs)
    assert fleet.mgr.query()["journal_pending"] == 0


def test_fleet_precarved_vfs_make_scale_out_pause_free(setup):
    """With spare VFs pre-carved at init (num_vfs > num_engines), a
    scale-out is a plain attach: no engine is ever paused for it."""
    from repro.serve import ServeFleet
    run, model, params = setup
    fleet = ServeFleet(run, params, num_engines=1, num_devices=4, slots=2,
                       max_len=48, num_vfs=2, workdir=tempfile.mkdtemp())
    assert len(fleet.pool.vfs) == 2
    fleet.scale_out()
    ops = [e["op"] for e in fleet.mgr.journal.entries()]
    assert ops.count("attach") == 2 and "pause" not in ops


def test_fleet_scale_in_refuses_inflight_prefill_then_parks(setup):
    """Satellite edge case: scale-in must refuse while the victim holds
    in-flight chunked-prefill jobs (they would strand), and succeed once
    drained — parking the engine's state on disk with its VF detached."""
    from repro.core import ManagerError
    from repro.serve import Request, ServeFleet
    run, model, params = setup
    fleet = ServeFleet(run, params, num_engines=1, num_devices=2, slots=2,
                       max_len=48, prefill_chunk=3,
                       workdir=tempfile.mkdtemp())
    eng = fleet.tenants["serve0"].engine
    fleet.submit(Request(rid=0, prompt=(np.arange(8) * 5) % 100,
                         max_new_tokens=2))
    fleet.step()
    assert eng._jobs                                # prefill in flight
    with pytest.raises(ManagerError, match="busy"):
        fleet.scale_in("serve0")
    assert fleet.tenants["serve0"].status == "running"   # refusal atomic
    res = fleet.drain()
    assert res.drained
    fleet.scale_in("serve0")
    assert fleet.tenants["serve0"].status == "detached"
    vf = next(iter(fleet.pool.vfs.values()))
    assert vf.owner is None and vf.devices          # devices reusable


def test_fleet_rebalance_moves_queue_and_keeps_tokens(setup):
    """Rebalance steals queued requests hot -> cold and migrates the hot
    victim; outputs equal an undisturbed run (queued requests have
    emitted nothing, in-flight ones survive the migrate bit-exactly)."""
    from repro.serve import Request, ServeFleet
    run, model, params = setup

    def serve(rebalance):
        fleet = ServeFleet(run, params, num_engines=2, num_devices=4,
                           slots=1, max_len=48,
                           workdir=tempfile.mkdtemp())
        reqs = [Request(rid=i, prompt=(np.arange(4) * (i + 2)) % 100,
                        max_new_tokens=3) for i in range(5)]
        # force the pile-up onto serve0 via direct engine submission
        for r in reqs:
            fleet.tenants["serve0"].engine.submit(r)
        fleet.step()
        if rebalance:
            moved = fleet.rebalance("serve0", "serve1")
            assert moved >= 1
            assert fleet.tenants["serve1"].engine.queue
        res = fleet.drain()
        assert res.drained and all(r.done and not r.error for r in reqs)
        assert fleet.mgr.query()["journal_pending"] == 0
        return [r.out for r in reqs]

    assert serve(False) == serve(True)


# ===========================================================================
# sim: the autoscale op + I11 after every action
# ===========================================================================
def test_generator_autoscale_rate_zero_is_byte_identical():
    base = ScenarioConfig(seed=7, serve_rate=0.35, num_ops=30)
    with_field = ScenarioConfig(seed=7, serve_rate=0.35, num_ops=30,
                                autoscale_rate=0.0)
    assert generate_scenario(base) == generate_scenario(with_field)


@pytest.mark.parametrize("arrival", ["ramp", "spike", "diurnal"])
def test_sim_autoscale_scenarios_hold_invariants(arrival):
    """Randomized serve + autoscale histories stay replay-stable with
    I1-I11 checked after every op, across arrival patterns."""
    took = []
    for seed in (1, 2, 4, 7):
        cfg = ScenarioConfig(seed=seed, serve_rate=0.45,
                             autoscale_rate=0.3, num_ops=40,
                             arrival=arrival)
        r = ScenarioRunner(cfg)
        res = r.run()
        assert res.fingerprint() == ScenarioRunner(cfg).run().fingerprint()
        took.extend(a.kind for a in r.autoscaler.history)
    assert "scale_out" in took       # the plane actually acts


def test_sim_i11_catches_seeded_unjustified_action(monkeypatch):
    """Seeded-bug demonstration: a planner that scales out on a COLD
    snapshot must be caught by I11 inside the harness, tagged with the
    reproducing seed/op#."""
    def bad_observe(self, s):
        return AutoscaleAction("scale_out", s, reason="seeded bug")
    monkeypatch.setattr(Autoscaler, "observe", bad_observe)
    cfg = ScenarioConfig(seed=1, serve_rate=0.45, autoscale_rate=0.3,
                         num_ops=40)
    with pytest.raises(InvariantViolation, match="I11"):
        ScenarioRunner(cfg).run()


def test_sim_crash_mid_scale_out_recovers_consistent(tmp_path):
    """PR-3 crashpoint fired mid-scale-out (inside the journaled attach
    the autoscaler's action executes through): recovery must leave an
    I8-clean journal/pool and be idempotent (I9 is asserted inside
    recover_manager)."""
    from repro.core.fault import InjectedCrash, crash_plane
    from repro.sim import check_invariants, recover_manager

    from repro.sim.harness import REJECTIONS

    cfg = ScenarioConfig(seed=2, serve_rate=0.45, autoscale_rate=0.3,
                         num_ops=40, arrival="ramp")
    r = ScenarioRunner(cfg, workdir=str(tmp_path))
    r._wd = str(tmp_path)              # _apply is driven without run()
    ops = generate_scenario(cfg)
    # drive the scenario; every autoscale op runs with the attach-window
    # crash point armed, so the FIRST scale_out the policy takes dies
    # mid-attach (scale_in/rebalance don't traverse the window)
    crashed = False
    try:
        for op in ops:
            if op.kind == "autoscale":
                crash_plane.arm("mid_record_write")
                try:
                    r._apply(op)
                except InjectedCrash:
                    crashed = True
                    break
                finally:
                    crash_plane.disarm()
            else:
                try:
                    r._apply(op)
                except REJECTIONS:
                    pass               # chaos ops are meant to be rejected
    finally:
        crash_plane.disarm()
    assert crashed, "no scale_out materialized for this seed"
    # the manager died mid-attach; rebuild and verify I1-I9
    r.mgr = recover_manager(r.mgr, r.tenants, policy=cfg.policy,
                            workdir=str(tmp_path), num_queues=2)
    check_invariants(r.mgr)
    assert r.mgr.query()["journal_pending"] == 0
