"""Request live migration: journaled KV block shipping between engines.

Covers the fleet-level surface of the migration tentpole on REAL
engines — mid-decode token identity, clean aborts that leave the Request
untouched (retry-safe), prefix-shared/CoW chains, scale-in that drains a
busy engine by migrating its work, engine-crash re-homing — plus the
sim-level scenario op and the I13 single-ownership invariant. The
crash-window matrix for the migration op lives in test_chaos.py (the
``CRASH_POINTS`` parametrization picks up the four migrate_* windows
automatically).
"""
import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import make_run_config
from repro.core.autoscaler import (AutoscaleAction, AutoscaleConfig,
                                   EngineStats, TelemetrySnapshot,
                                   justify_action)
from repro.core import ManagerError, SVFFManager
from repro.core.pool import DevicePool
from repro.core.staging import StagingEngine
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ServeFleet
from repro.serve.paged import CacheExhausted
from repro.sim.invariants import InvariantViolation, check_invariants
from repro.sim.tenant import SimServeTenant


@pytest.fixture(scope="module")
def setup():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


def _fleet(run, params, **kw):
    kw.setdefault("num_engines", 2)
    kw.setdefault("num_devices", 4)
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    return ServeFleet(run, params, workdir=tempfile.mkdtemp(), **kw)


def _reference(run, params, specs, **engine_kw):
    """Token oracle: the same requests served by one undisturbed engine."""
    engine_kw.setdefault("slots", max(2, len(specs)))
    engine_kw.setdefault("max_len", 48)
    engine_kw.setdefault("paged", True)
    engine_kw.setdefault("page_size", 8)
    eng = ServeEngine(run, params, **engine_kw)
    reqs = [Request(rid=rid, prompt=np.array(p), max_new_tokens=n)
            for rid, p, n in specs]
    for r in reqs:
        eng.submit(r)
    res = eng.run_until_idle()
    assert res.drained
    return {r.rid: list(r.out) for r in reqs}


# ===========================================================================
# mid-decode migration: token identity + telemetry
# ===========================================================================
def test_mid_decode_migration_is_token_identical(setup):
    run, model, params = setup
    specs = [(0, (np.arange(6) * 5 + 2) % 100, 6),
             (1, (np.arange(9) * 3) % 100, 5)]
    want = _reference(run, params, specs)
    fleet = _fleet(run, params)
    reqs = [Request(rid=rid, prompt=np.array(p), max_new_tokens=n)
            for rid, p, n in specs]
    placed = [fleet.submit(r) for r in reqs]
    assert placed == ["serve0", "serve1"]
    for _ in range(2):
        fleet.step()
    victim = reqs[0]
    assert victim.out and not victim.done          # genuinely mid-decode
    res = fleet.migrate_request("serve0", "serve1", victim.rid)
    assert res is not None and res["rid"] == victim.rid
    assert res["blocks"] >= 1                      # KV pages really shipped
    assert fleet.tenants["serve1"].owns_request(victim.rid)
    assert not fleet.tenants["serve0"].owns_request(victim.rid)
    assert fleet.tenants["serve0"].engine._migrating == {}
    assert fleet.mgr.query()["journal_pending"] == 0
    done = fleet.drain()
    assert res is not None and sorted(r.rid for r in done) == [0, 1]
    for r in reqs:
        assert r.done and not r.error
        assert list(r.out) == want[r.rid], (r.rid, r.out, want[r.rid])
    # the hand-off is visible in fleet telemetry, attributed to the source
    desc = fleet.telemetry.describe()["serve0"]
    assert desc["migrations_attempted"] == 1
    assert desc["migrations_completed"] == 1
    assert desc["migrations_aborted"] == 0
    assert desc["migration_blocks"] == res["blocks"]
    snap = fleet.telemetry_snapshot()
    stats = {e.tid: e for e in snap.engines}
    assert stats["serve0"].migrations_completed == 1
    assert stats["serve0"].migration_blocks_shipped == res["blocks"]


def test_aborted_migration_is_side_effect_free_and_retryable(setup):
    """Satellite regression: a target-side CacheExhausted must leave the
    Request object untouched (no done/error flags, tokens intact, still
    decoding on the source) so the SAME migration can retry later and
    complete token-identically."""
    run, model, params = setup
    specs = [(0, (np.arange(8) * 7 + 1) % 100, 8)]
    want = _reference(run, params, specs)
    # 5 pages (page 0 reserved -> 4 usable) per engine: two 2-page
    # residents fill serve1's pool AND both its slots
    fleet = _fleet(run, params, num_pages=5)
    victim = Request(rid=0, prompt=np.array(specs[0][1]), max_new_tokens=8)
    fleet.tenants["serve0"].engine.submit(victim)
    blockers = [Request(rid=10 + i, prompt=(np.arange(12) * (i + 3)) % 100,
                        max_new_tokens=6) for i in range(2)]
    for b in blockers:
        fleet.tenants["serve1"].engine.submit(b)
    for _ in range(2):
        fleet.step()
    assert victim.out and not victim.done
    before = list(victim.out)
    with pytest.raises(CacheExhausted):
        fleet.mgr.migrate_request(fleet.tenants["serve0"],
                                  fleet.tenants["serve1"], victim.rid)
    # clean abort: journal rolled back, request untouched on the source
    assert victim.done is False and victim.error is None
    assert list(victim.out) == before
    assert fleet.tenants["serve0"].owns_request(victim.rid)
    assert not fleet.tenants["serve1"].owns_request(victim.rid)
    assert fleet.tenants["serve0"].engine._migrating == {}
    assert fleet.mgr.query()["journal_pending"] == 0
    # the wrapper's bounded retries also abort while the target is full
    assert fleet.migrate_request("serve0", "serve1", victim.rid) is None
    assert fleet.telemetry.migrations_aborted["serve0"] >= 1
    assert fleet.telemetry.migrations_completed["serve0"] == 0
    # free the target, retry the SAME request: completes, token-identical
    fleet.tenants["serve1"].engine.run_until_idle()
    assert not victim.done
    res = fleet.migrate_request("serve0", "serve1", victim.rid)
    assert res is not None
    assert fleet.tenants["serve1"].owns_request(victim.rid)
    fleet.drain()
    assert victim.done and not victim.error
    assert list(victim.out) == want[0]


# ===========================================================================
# prefix sharing / CoW across migration
# ===========================================================================
def test_migrating_prefix_shared_requests_reshare_on_target(setup):
    run, model, params = setup
    base = (np.arange(16) * 3 + 1) % 100           # two FULL shared pages
    pa = np.concatenate([base, (np.arange(4) * 7) % 100])
    pb = np.concatenate([base, (np.arange(4) * 11 + 5) % 100])
    specs = [(0, pa, 5), (1, pb, 5)]
    want = _reference(run, params, specs, share_prefix=True)
    fleet = _fleet(run, params, share_prefix=True)
    ra = Request(rid=0, prompt=pa, max_new_tokens=5)
    rb = Request(rid=1, prompt=pb, max_new_tokens=5)
    src = fleet.tenants["serve0"].engine
    dst = fleet.tenants["serve1"].engine
    src.submit(ra)
    src.submit(rb)
    for _ in range(2):
        fleet.step()
    assert ra.out and rb.out
    head = src.alloc.pages_of(ra.rid)[0]
    assert src.alloc.refcount(head) == 2           # really sharing
    # migrate rb away: the source's shared head pages drop to refcount 1
    assert fleet.migrate_request("serve0", "serve1", rb.rid) is not None
    assert src.alloc.refcount(head) == 1
    assert src.alloc.check_invariants() is None    # I12 on the source
    assert dst.alloc.check_invariants() is None    # I12 on the target
    # migrate ra too: its full prompt pages RE-SHARE against the prefix
    # rb registered on the target (the partial tail page ships copied)
    assert fleet.migrate_request("serve0", "serve1", ra.rid) is not None
    assert dst.alloc.shared_count(ra.rid) == 2
    assert dst.alloc.refcount(dst.alloc.pages_of(ra.rid)[0]) == 2
    assert src.alloc.check_invariants() is None
    assert dst.alloc.check_invariants() is None
    fleet.drain()
    for r in (ra, rb):
        assert r.done and not r.error
        assert list(r.out) == want[r.rid], (r.rid, r.out, want[r.rid])


# ===========================================================================
# scale_in under load drains by migration
# ===========================================================================
def test_scale_in_under_load_migrates_work_to_siblings(setup):
    run, model, params = setup
    rng = np.random.default_rng(17)
    specs = [(i, rng.integers(0, 100, int(rng.integers(4, 9))), 6)
             for i in range(4)]
    want = _reference(run, params, specs)
    fleet = _fleet(run, params, slots=4)
    reqs = [Request(rid=rid, prompt=np.array(p), max_new_tokens=n)
            for rid, p, n in specs]
    for r in reqs:
        fleet.submit(r)
    for _ in range(2):
        fleet.step()
    busy = fleet.tenants["serve1"]
    assert busy.load > 0                           # scale_in of a BUSY engine
    fleet.scale_in("serve1")
    assert busy.status == "detached"
    for r in reqs:
        assert fleet.tenants["serve0"].owns_request(r.rid)
    assert fleet.mgr.query()["journal_pending"] == 0
    done = fleet.drain()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    for r in reqs:
        assert r.done and not r.error
        assert list(r.out) == want[r.rid], (r.rid, r.out, want[r.rid])


def test_scale_in_refuses_typed_when_no_sibling_has_capacity(setup):
    run, model, params = setup
    fleet = _fleet(run, params, num_engines=1)
    req = Request(rid=0, prompt=np.arange(6) % 100, max_new_tokens=6)
    fleet.submit(req)
    fleet.step()
    with pytest.raises(ManagerError, match="no running sibling"):
        fleet.scale_in("serve0")
    # the refusal stranded nothing: the engine still serves the request
    assert fleet.tenants["serve0"].owns_request(req.rid)
    fleet.drain()
    assert req.done and not req.error


# ===========================================================================
# engine crash: live requests re-home onto siblings
# ===========================================================================
def test_engine_crash_rehomes_live_requests_zero_loss(setup):
    run, model, params = setup
    rng = np.random.default_rng(23)
    specs = [(i, rng.integers(0, 100, int(rng.integers(4, 8))), 5)
             for i in range(4)]
    want = _reference(run, params, specs)
    fleet = _fleet(run, params, slots=4)
    reqs = [Request(rid=rid, prompt=np.array(p), max_new_tokens=n)
            for rid, p, n in specs]
    for r in reqs:
        fleet.submit(r)
    for _ in range(2):
        fleet.step()
    crashed = [r for r in reqs
               if fleet.tenants["serve0"].owns_request(r.rid)]
    assert crashed                                 # the crash hits live work
    out = fleet.recover_engine("serve0")
    assert sorted(rid for rid, _ in out["rehomed"]) == \
        sorted(r.rid for r in crashed if not r.done)
    assert fleet.tenants["serve0"].load == 0
    assert fleet.tenants["serve0"].status == "running"
    done = fleet.drain()
    assert {r.rid for r in done} >= {r.rid for r in crashed}
    for r in reqs:
        assert r.done and not r.error
        # recompute is bit-identical: same prompt, same seeded sampler
        assert list(r.out) == want[r.rid], (r.rid, r.out, want[r.rid])


def test_engine_crash_recovery_refuses_without_capacity(setup):
    run, model, params = setup
    fleet = _fleet(run, params, num_engines=1)
    req = Request(rid=0, prompt=np.arange(5) % 100, max_new_tokens=6)
    fleet.submit(req)
    fleet.step()
    before = list(req.out)
    with pytest.raises(ManagerError, match="no sibling"):
        fleet.recover_engine("serve0")
    # refusal happened BEFORE any mutation: nothing was reset or cleared
    assert list(req.out) == before
    assert fleet.tenants["serve0"].owns_request(req.rid)


# ===========================================================================
# control plane: in-flight load justifies a rebalance
# ===========================================================================
def test_rebalance_justified_by_inflight_only_load():
    hot = EngineStats(tid="a", index=0, status="running", load=6,
                      queue_depth=0, inflight=6)
    cold = EngineStats(tid="b", index=1, status="running", load=0)
    snap = TelemetrySnapshot(epoch=1, slo_max_load=6, engines=(hot, cold))
    cfg = AutoscaleConfig(rebalance_gap=4)
    act = AutoscaleAction("rebalance", snap, victim="a", target="b")
    assert justify_action(act, cfg) is None
    # nothing queued AND nothing in flight still fails justification
    idle_hot = dataclasses.replace(hot, queue_depth=0, inflight=0)
    snap2 = TelemetrySnapshot(epoch=2, slo_max_load=6,
                              engines=(idle_hot, cold))
    act2 = AutoscaleAction("rebalance", snap2, victim="a", target="b")
    assert "nothing queued or in flight" in justify_action(act2, cfg)


# ===========================================================================
# sim plane: scenario op + I13
# ===========================================================================
def _sim_mgr(workdir, tenants):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)), max_vfs=4)
    mgr = SVFFManager(pool, workdir=str(workdir),
                      staging=StagingEngine(num_queues=2),
                      scheduler="first_fit")
    mgr.init(len(tenants), tenants, devices_per_vf=2)
    return mgr


def test_scenario_traffic_with_migrations_holds_invariants(tmp_path):
    from repro.sim.harness import ScenarioRunner
    from repro.sim.scenario import ScenarioConfig, generate_scenario

    # default streams are byte-identical with the knob at 0
    assert generate_scenario(ScenarioConfig(seed=3)) == \
        generate_scenario(ScenarioConfig(seed=3, migrate_rate=0.0))
    cfg = ScenarioConfig(seed=1, num_ops=40, serve_rate=0.5,
                         migrate_rate=0.25, autoscale_rate=0.1)
    ops = generate_scenario(cfg)
    assert any(o.kind == "migrate_request" for o in ops)
    runner = ScenarioRunner(cfg)
    runner.run()                    # invariants (incl. I13) run per-op
    migrated = sum(getattr(tn, "migrations_in", 0)
                   for tn in runner.tenants.values())
    assert migrated > 0             # migrations actually executed


def test_i13_catches_request_live_on_two_engines(tmp_path):
    sv0 = SimServeTenant("sv0", seed=5)
    sv1 = SimServeTenant("sv1", seed=6)
    mgr = _sim_mgr(tmp_path, [sv0, sv1])
    sv0.submit_burst(3)
    for _ in range(6):
        sv0.run_steps(1)
        if sv0.peek_migratable() is not None:
            break
    assert sv0.peek_migratable() is not None
    check_invariants(mgr)                          # healthy before
    # corrupt: admit on the target WITHOUT releasing the source
    payload = sv0.extract_request()
    sv1.admit_migrated(payload, payload["state"])
    with pytest.raises(InvariantViolation, match="I13"):
        check_invariants(mgr)
    # roll the target admission back: healthy again (abort really is
    # side-effect-free on shared ownership state)
    sv1.abort_incoming(payload["rid"])
    sv0.abort_migration(payload["rid"])
    check_invariants(mgr)
