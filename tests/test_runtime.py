"""Runtime-layer unit tests: sharding rules, roofline math, optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (MULTI_POD_MESH, OptimizerConfig, SINGLE_POD_MESH,
                           make_run_config)
from repro.models.params import param_shapes
from repro.runtime.partitioning import ShardingRules
from repro.runtime.roofline import Roofline, model_flops_estimate
from repro.train.optim import build_optimizer, clip_by_global_norm


def rules_for(arch, shape="train_4k", mesh=SINGLE_POD_MESH, **kw):
    run = make_run_config(arch, shape, mesh=mesh, **kw)
    return run, ShardingRules(mesh, run)


# ---------------------------------------------------------------------------
def test_param_specs_cover_all_archs():
    """Every leaf of every full-size arch gets a divisibility-valid spec."""
    from repro.configs import list_archs
    for arch in list_archs():
        run, rules = rules_for(arch)
        shapes = param_shapes(run.model)
        specs = rules.param_specs(shapes)

        def check(path, sd, spec):
            for dim, ax in zip(sd.shape, tuple(spec) + (None,) *
                               (len(sd.shape) - len(tuple(spec)))):
                if ax is None:
                    continue
                sz = rules._size(ax)
                assert dim % sz == 0, (arch, path, sd.shape, spec)
        jax.tree_util.tree_map_with_path(
            lambda p, s, sp: check(p, s, sp), shapes, specs,
            is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def test_attn_mode_selection():
    _, r_phi = rules_for("phi3-mini-3.8b")        # kv=32 % 16 == 0
    assert r_phi.attn_mode(32) == "heads"
    _, r_llama = rules_for("llama3-8b")           # kv=8 % 16 != 0
    assert r_llama.attn_mode(32) == "seq"
    _, r_unit = rules_for("llama3-8b", mesh=__import__(
        "repro.configs", fromlist=["UNIT_MESH"]).UNIT_MESH)
    assert r_unit.attn_mode(32) == "heads"        # no model axis


def test_kv_cache_spec_long_context_batch1():
    """long_500k (batch 1): batch can't shard, sequence shards over all."""
    run, rules = rules_for("jamba-1.5-large-398b", "long_500k")
    spec = rules.spec("kv_cache", (1, 524288, 8, 128))
    assert spec[0] is None
    assert spec[1] is not None                    # seq sharded


def test_moe_expert_spec():
    run, rules = rules_for("arctic-480b")
    spec = rules.spec("expert", (128, 2048, 10, 7168))
    assert spec[0] == "model" and spec[1] == "data"


def test_multipod_fsdp_axes():
    run, rules = rules_for("llama3-8b", mesh=MULTI_POD_MESH)
    assert rules.dp_axes == ("pod", "data")
    spec = rules.param_spec("params/decoder/layers/block0/ffn/wi",
                            (32, 4096, 14336))
    assert spec[0] is None                        # stacked period dim
    assert spec[1] == ("pod", "data")             # FSDP over both
    assert spec[2] == "model"


def test_lm_head_sp_mode():
    from repro.configs import ShardingConfig
    run, rules = rules_for("llama3-8b",
                           sharding=ShardingConfig(seq_shard_acts=True))
    spec = rules.param_spec("params/lm_head", (4096, 128256))
    assert spec[1] is None                        # vocab replicated in SP


# ---------------------------------------------------------------------------
def test_roofline_terms_and_bound():
    rf = Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                  hlo_flops=197e12, hlo_bytes=819e9 * 2,
                  collective_bytes=50e9,
                  collective_detail={"bytes_by_op": {"all-reduce": 50e9}},
                  model_flops=197e12 * 256)
    assert rf.compute_s == pytest.approx(1.0)
    assert rf.memory_s == pytest.approx(2.0)
    assert rf.collective_s == pytest.approx(2.0)  # AR counts 2x
    assert rf.bound in ("memory", "collective")
    assert rf.step_s == pytest.approx(2.0)
    assert rf.mfu == pytest.approx(0.5)


def test_model_flops_estimate_kinds():
    from repro.configs import SHAPES, get_model_config
    cfg = get_model_config("llama3-8b")
    n = cfg.active_param_count()
    assert model_flops_estimate(cfg, SHAPES["train_4k"]) == pytest.approx(
        6.0 * n * 256 * 4096)
    assert model_flops_estimate(cfg, SHAPES["decode_32k"]) == pytest.approx(
        2.0 * n * 128)
    moe = get_model_config("olmoe-1b-7b")
    assert moe.active_param_count() < moe.param_count()


# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    opt = build_optimizer(OptimizerConfig(name="adamw", lr=0.1))
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}            # d/dw w^2
        params, state = opt.update(grads, state, params, 0.1)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_adafactor_factored_state_small():
    opt = build_optimizer(OptimizerConfig(name="adafactor"))
    params = {"w": jnp.zeros((128, 256)), "b": jnp.zeros((128,))}
    state = opt.init(params)
    assert state["f"]["w"]["vr"].shape == (128,)
    assert state["f"]["w"]["vc"].shape == (256,)
    assert state["f"]["b"]["v"].shape == (128,)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
    assert nbytes < params["w"].nbytes / 10       # ZeRO-friendly


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    gn2 = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert gn2 == pytest.approx(1.0, rel=1e-4)
