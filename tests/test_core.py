"""SVFF core behaviour tests: VF state machine, pool invariants, pause
transparency (the paper's §IV-B1 semantics), manager reconf, QMP, records,
fault recovery. Multi-device tests run in a subprocess with a forced
8-device CPU pool (XLA locks the device count at first init)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax

from repro.configs import make_run_config
from repro.core import (DevicePool, PoolError, VFState, VFTransitionError,
                        VirtualFunction)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# single-device unit tests
# ---------------------------------------------------------------------------
def test_vf_state_machine():
    vf = VirtualFunction(vf_id="0000:03:00.1")
    vf.assign_devices(jax.devices()[:1], (1, 1))
    with pytest.raises(VFTransitionError):
        vf.transition(VFState.PAUSED)          # detached -> paused illegal
    vf.transition(VFState.ATTACHED)
    vf.transition(VFState.PAUSED)
    with pytest.raises(VFTransitionError):
        vf.transition(VFState.DETACHED)        # paused -> detached illegal
    vf.transition(VFState.ATTACHED)
    vf.transition(VFState.DETACHED)


def test_pool_set_num_vfs_blocks_attached():
    """The SR-IOV limitation (paper §IV-B1): #VF can't change while VFs
    are attached — but paused VFs don't block it."""
    pool = DevicePool(devices=jax.devices())
    pool.set_num_vfs(1, devices_per_vf=1)
    vf = list(pool.vfs.values())[0]
    vf.owner = "vm0"
    vf.transition(VFState.ATTACHED)
    with pytest.raises(PoolError):
        pool.set_num_vfs(0)
    vf.transition(VFState.PAUSED)
    vf.release_devices()
    pool.set_num_vfs(1, devices_per_vf=1)      # paused VF survives
    assert vf.vf_id in pool.vfs


def test_pool_isolation_invariant():
    pool = DevicePool(devices=jax.devices())
    pool.set_num_vfs(1, devices_per_vf=1)
    rogue = VirtualFunction(vf_id="0000:03:00.9")
    rogue.assign_devices(jax.devices()[:1], (1, 1))
    pool.vfs[rogue.vf_id] = rogue
    with pytest.raises(PoolError):
        pool._check_invariants()               # same device, two VFs


def test_max_vfs_limit():
    pool = DevicePool(devices=jax.devices(), max_vfs=4)
    with pytest.raises(PoolError):
        pool.set_num_vfs(5)


# ---------------------------------------------------------------------------
# multi-device behaviour (subprocess with 8 CPU devices)
# ---------------------------------------------------------------------------
def run_in_pool_subprocess(body: str) -> dict:
    """Run `body` with an 8-device pool; it must print a JSON result."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import numpy as np
        from repro.configs import make_run_config
        from repro.core import (ControlPlane, DevicePausedError, DevicePool,
                                SVFFManager, StagingEngine, Supervisor,
                                Tenant, VFState)
        import tempfile
        WORKDIR = tempfile.mkdtemp(prefix='svff_test_')
        run = make_run_config('svff-bench', 'train_4k', smoke=True)
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_pause_transparency_and_state_preservation(tmp_path):
    """The paper's central claim: pausing detaches from the host but not
    the guest; after unpause the tenant continues with bit-identical state
    and no re-'realize' (executable cache hit)."""
    res = run_in_pool_subprocess("""
        pool = DevicePool()
        mgr = SVFFManager(pool, workdir=WORKDIR)
        tn = Tenant('vm0', run, local_batch=2, seq_len=16)
        mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=4)
        tn.run_steps(2)
        before = jax.tree.leaves(tn.export_state()['params'])[1]
        before = np.asarray(before).copy()
        nexec = len(tn._exec_cache)

        mgr.pause(tn)
        visible = tn.query()                    # guest still sees device
        blocked = False
        try:
            tn.run_steps(1)
        except DevicePausedError:
            blocked = True
        vf = pool.find(tn.vf_id)
        mgr.unpause(tn)
        after = np.asarray(jax.tree.leaves(tn.export_state()['params'])[1])
        tn.run_steps(1)
        print(json.dumps({
            'visible_while_paused': visible['status'] == 'paused',
            'vf_kept_identity': visible['vf'] is not None,
            'io_blocked': blocked,
            'devices_released': True,
            'state_identical': bool((before == after).all()),
            'exec_cache_hit': len(tn._exec_cache) == nexec,
            'steps_after': tn.steps_done,
        }))
    """)
    assert res == {"visible_while_paused": True, "vf_kept_identity": True,
                   "io_blocked": True, "devices_released": True,
                   "state_identical": True, "exec_cache_hit": True,
                   "steps_after": 3}


@pytest.mark.slow
def test_reconf_grows_pool_without_disturbing_live_tenants():
    """Paper's headline scenario: attach additional VFs to new VMs without
    affecting devices already attached to other VMs."""
    res = run_in_pool_subprocess("""
        pool = DevicePool()
        mgr = SVFFManager(pool, workdir=WORKDIR)
        a = Tenant('vmA', run, local_batch=2, seq_len=16, seed=1)
        mgr.init(num_vfs=1, tenants=[a], devices_per_vf=8)
        a.run_steps(2)
        sA = np.asarray(jax.tree.leaves(a.export_state()['params'])[1]).copy()
        # grow to 2 VFs (each 4 devices) and attach a new tenant
        b = Tenant('vmB', run, local_batch=2, seq_len=16, seed=2)
        mgr.tenants['vmB'] = b
        t = mgr.reconf(num_vfs=2, new_tenants=[b], devices_per_vf=4)
        a.run_steps(1); b.run_steps(1)
        sA2 = np.asarray(jax.tree.leaves(a.export_state()['params'])[1])
        print(json.dumps({
            'timings_keys': sorted(t.keys()),
            'a_steps': a.steps_done, 'b_steps': b.steps_done,
            'a_continued': bool(sA2.shape == sA.shape),
            'a_mesh': list(pool.find(a.vf_id).mesh_shape),
        }))
    """)
    assert res["timings_keys"] == ["add_vf", "change_num_vf", "remove_vf",
                                   "rescan", "total"]
    assert res["a_steps"] == 3 and res["b_steps"] == 1
    assert res["a_continued"]


@pytest.mark.slow
def test_elastic_reshard_on_unpause():
    """Unpause onto a different slice size: state is resharded, training
    continues — elastic scaling through the pause mechanism."""
    res = run_in_pool_subprocess("""
        pool = DevicePool()
        mgr = SVFFManager(pool, workdir=WORKDIR)
        tn = Tenant('vm0', run, local_batch=2, seq_len=16)
        mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
        tn.run_steps(1)
        mgr.pause(tn)
        vf = pool.find(tn.vf_id)
        pool.set_num_vfs(1, devices_per_vf=8)   # repartition under pause
        mgr.unpause(tn, num_devices=8)
        tn.run_steps(1)
        print(json.dumps({
            'new_mesh': list(pool.find(tn.vf_id).mesh_shape),
            'steps': tn.steps_done,
        }))
    """)
    assert res["steps"] == 2
    import math
    assert math.prod(res["new_mesh"]) == 8


@pytest.mark.slow
def test_detach_attach_roundtrip_via_disk():
    res = run_in_pool_subprocess("""
        pool = DevicePool()
        mgr = SVFFManager(pool, workdir=WORKDIR)
        tn = Tenant('vm0', run, local_batch=2, seq_len=16)
        mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=4)
        tn.run_steps(2)
        w = np.asarray(jax.tree.leaves(tn.export_state()['params'])[1]).copy()
        mgr.detach(tn)
        detached = tn.status == 'detached' and tn.vf_id is None
        mgr.attach(tn)
        w2 = np.asarray(jax.tree.leaves(tn.export_state()['params'])[1])
        tn.run_steps(1)
        print(json.dumps({
            'detached': detached,
            'state_identical': bool((w == w2).all()),
            'steps': tn.steps_done,
        }))
    """)
    assert res == {"detached": True, "state_identical": True, "steps": 3}


@pytest.mark.slow
def test_qmp_socket_and_fault_recovery():
    res = run_in_pool_subprocess("""
        import socket
        pool = DevicePool()
        mgr = SVFFManager(pool, workdir=WORKDIR)
        t0 = Tenant('vm0', run, local_batch=2, seq_len=16)
        mgr.init(num_vfs=2, tenants=[t0], devices_per_vf=4)
        cp = ControlPlane(mgr)
        cp.serve_unix(WORKDIR + '/qmp.sock')
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.connect(WORKDIR + '/qmp.sock')
        f = s.makefile('rw')
        greeting = json.loads(f.readline())
        f.write(json.dumps({'execute': 'query-vfs'}) + '\\n'); f.flush()
        vfs = json.loads(f.readline())
        f.write(json.dumps({'execute': 'device_pause',
                            'arguments': {'id': 'vm0'}}) + '\\n'); f.flush()
        pz = json.loads(f.readline())
        f.write(json.dumps({'execute': 'device_pause',
                            'arguments': {'id': 'vm0', 'pause': False}})
                + '\\n'); f.flush()
        upz = json.loads(f.readline())
        cp.shutdown()
        # fault injection -> supervisor migrates
        sup = Supervisor(mgr)
        t0.inject_failure()
        sup.run_round(1)
        t0.run_steps(1)
        print(json.dumps({
            'greeting': 'QMP' in greeting,
            'nvfs': vfs['return']['num_vfs'],
            'pause_ok': 'return' in pz, 'unpause_ok': 'return' in upz,
            'events': [e['kind'] for e in sup.events],
            'recovered_steps': t0.steps_done,
        }))
    """)
    assert res["greeting"] and res["nvfs"] == 2
    assert res["pause_ok"] and res["unpause_ok"]
    assert res["events"] == ["failure", "migrated"]
    assert res["recovered_steps"] >= 1
