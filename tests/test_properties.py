"""Property-based tests (hypothesis) on system invariants."""
import math

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.pool import DevicePool, _default_mesh_shape
from repro.core.staging import StagingEngine
from repro.core.vf import VFState
from repro.kernels import ref
from repro.runtime.hlo import collective_stats

HSET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
@given(n=st.integers(1, 4096))
@HSET
def test_default_mesh_shape_factors(n):
    a, b = _default_mesh_shape(n)
    assert a * b == n and a >= b


@given(ndev=st.integers(1, 16), nvf=st.integers(0, 8),
       per=st.integers(1, 4))
@HSET
def test_pool_partition_invariants(ndev, nvf, per):
    """Whatever the requested partition, VF device sets stay disjoint,
    within-pool, and correctly sized — or the pool refuses."""
    devices = [f"dev{i}" for i in range(ndev)]   # pool never touches them
    pool = DevicePool(devices=devices)
    pool._rescanned = True
    try:
        created = pool.set_num_vfs(nvf, devices_per_vf=per)
    except Exception:
        assert nvf * per > ndev or nvf > pool.max_vfs
        return
    assert len(created) == nvf
    seen = set()
    for vf in pool.vfs.values():
        assert len(vf.devices) == math.prod(vf.mesh_shape)
        for d in vf.devices:
            assert d not in seen
            assert d in devices
            seen.add(d)


# ---------------------------------------------------------------------------
@given(shape=st.sampled_from([(4, 256), (2, 3, 512), (16, 1024)]),
       block=st.sampled_from([128, 256]),
       scale_pow=st.integers(-8, 8))
@HSET
def test_qdma_roundtrip_error_bound(shape, block, scale_pow):
    """Quantization round-trip error <= half a quantization step, for any
    magnitude scale (property over 16 orders of magnitude)."""
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(shape) * (10.0 ** scale_pow)).astype(np.float32)
    q, s = ref.qdma_pack_ref(jnp.asarray(x), block=block)
    xx = np.asarray(ref.qdma_unpack_ref(q, s))
    step = np.repeat(np.asarray(s), block, axis=-1).reshape(x.shape)
    assert (np.abs(xx - x) <= 0.5 * step + 1e-30).all()


@given(seed=st.integers(0, 10_000), compression=st.sampled_from(
    ["none", "int8"]), pipeline=st.booleans())
@HSET
def test_staging_roundtrip(seed, compression, pipeline):
    """save->restore is identity (bit-exact without compression; bounded
    error with int8) and preserves tree structure/dtypes — for both the
    pipelined descriptor engine and the PR-1 baseline."""
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((8, 512)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 100, (4,)), jnp.int32),
                  "d": jnp.asarray(rng.standard_normal((3, 5)),
                                   jnp.float32)},
            "s": jnp.float32(3.25)}
    eng = StagingEngine(num_queues=2, compression=compression,
                        min_quant_size=1024, pipeline=pipeline)
    staged = eng.save(tree)
    out = eng.restore(staged)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for k, (x, y) in enumerate(zip(jax.tree.leaves(tree),
                                   jax.tree.leaves(out))):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        if compression == "none":
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(x, y, atol=np.abs(x).max() / 64)


@given(shape=st.sampled_from([(1023, 17), (7, 3, 129), (4097,), (33, 255),
                              (2, 1, 5, 31)]),
       chunk_bytes=st.sampled_from([256, 1024, 65536]),
       transport=st.sampled_from(["stream", "borrow"]))
@HSET
def test_descriptor_chunking_roundtrips_odd_shapes(shape, chunk_bytes,
                                                   transport):
    """Row-chunk descriptors are an implementation detail: any leaf shape
    (odd rows, tiny trailing dims, high rank) must reassemble bit-exactly
    for any chunk size and transport."""
    rng = np.random.default_rng(hash((shape, chunk_bytes)) % 2**32)
    tree = {"x": jnp.asarray(rng.standard_normal(shape), jnp.float32),
            "i": jnp.asarray(rng.integers(-5, 5, shape), jnp.int8)}
    eng = StagingEngine(num_queues=3, chunk_bytes=chunk_bytes,
                        transport=transport)
    out = eng.restore(eng.save(tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert np.asarray(x).dtype == np.asarray(y).dtype


@given(seed=st.integers(0, 1000), compression=st.sampled_from(
    ["none", "int8"]), incremental=st.booleans())
@HSET
def test_staging_stats_symmetric(seed, compression, incremental):
    """save/restore TransferStats agree on one unit of account: bytes
    that actually cross the link (packed bytes for quantized leaves,
    counted once). A save's skips are visible as skipped_bytes, so
    moved+skipped always equals the restore's moved."""
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((16, 512)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((8, 256)), jnp.float32),
            "c": jnp.asarray(rng.integers(0, 9, (31,)), jnp.int32)}
    eng = StagingEngine(num_queues=2, compression=compression,
                        min_quant_size=1024, incremental=incremental)
    eng.save(tree, tenant="t0")
    first = eng.last_stats
    assert first.skipped_bytes == 0
    staged = eng.save(tree, tenant="t0")          # may skip via memo
    save_stats = eng.last_stats
    eng.restore(staged)
    restore_stats = eng.last_stats
    assert (save_stats.bytes_moved + save_stats.skipped_bytes
            == restore_stats.bytes_moved)
    assert first.bytes_moved == restore_stats.bytes_moved
    if incremental:
        assert save_stats.bytes_moved == 0        # identical jax leaves
        assert save_stats.skipped_bytes == restore_stats.bytes_moved
    assert save_stats.logical_bytes == sum(
        x.nbytes for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
@given(a=st.integers(1, 64), b=st.integers(1, 64), c=st.integers(1, 64))
@HSET
def test_collective_parser_counts_bytes(a, b, c):
    """HLO parser sums shapes correctly for synthetic instruction lines."""
    txt = (f"  %ag = bf16[{a},{b}] all-gather(x), dims={{0}}\n"
           f"  %ar = (f32[{c}], f32[{a},{b},{c}]) all-reduce(y, z)\n"
           f"  %nope = f32[{a}] add(u, v)\n")
    stats = collective_stats(txt)
    assert stats.bytes_by_op["all-gather"] == a * b * 2
    assert stats.bytes_by_op["all-reduce"] == 4 * c + 4 * a * b * c
    assert stats.total_count == 2


# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 1000), S=st.sampled_from([32, 64]),
       chunk=st.sampled_from([8, 16, 32]))
@HSET
def test_ssd_chunk_invariance(seed, S, chunk):
    """Chunk size is an implementation detail: results must not depend on
    it (the recurrence semantics are chunk-free)."""
    from repro.models.ssm import ssd_chunked
    rng = jax.random.key(seed)
    ks = jax.random.split(rng, 4)
    B, H, hd, N = 1, 2, 8, 4
    xdt = jax.random.normal(ks[0], (B, S, H, hd))
    Bv = jax.random.normal(ks[1], (B, S, N))
    Cv = jax.random.normal(ks[2], (B, S, N))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y1, h1 = ssd_chunked(xdt, Bv, Cv, la, chunk=chunk)
    y2, h2 = ssd_chunked(xdt, Bv, Cv, la, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)


@given(seed=st.integers(0, 1000))
@HSET
def test_attention_gqa_equals_repeated_mha(seed):
    """GQA(K) == MHA with kv heads explicitly repeated G times."""
    from repro.models.attention import attention_ref
    ks = jax.random.split(jax.random.key(seed), 3)
    B, S, H, K, hd = 1, 16, 4, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    o1 = attention_ref(q, k, v, causal=True)
    krep = jnp.repeat(k, H // K, axis=2)
    vrep = jnp.repeat(v, H // K, axis=2)
    o2 = attention_ref(q, krep, vrep, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5,
                               rtol=1e-5)
