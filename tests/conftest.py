import os
import sys

# Tests must see the real (single) CPU device — the 512-device override is
# strictly dryrun.py-local. Some tests spawn subprocesses that set their own
# XLA_FLAGS (multi-device pool tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is a declared test dependency (pyproject [test] extra), but
# hermetic containers may lack it — fall back to the deterministic shim so
# tests/test_properties.py still collects and runs.
sys.path.insert(0, os.path.dirname(__file__))
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _minihypothesis
    _minihypothesis.install()

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)


def _sweep_results_present() -> bool:
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        return False
    done = [f for f in os.listdir(d)
            if f.endswith(".json") and "-" not in f.split("__")[-1]]
    return len(done) >= 80


def pytest_collection_modifyitems(config, items):
    """``sweep``-marked tests assert over the COMMITTED full-sweep results
    (results/dryrun, 80 cells). Checkouts without them deselect the tests
    at collection time — visible in the deselection count, unlike the old
    silent runtime skip. ``SVFF_FULL_SWEEP=1`` forces them on (the test
    then fails loudly if the results really are missing)."""
    if os.environ.get("SVFF_FULL_SWEEP") == "1" or _sweep_results_present():
        return
    keep, drop = [], []
    for item in items:
        (drop if item.get_closest_marker("sweep") else keep).append(item)
    if drop:
        config.hook.pytest_deselected(items=drop)
        items[:] = keep
