import os

# Tests must see the real (single) CPU device — the 512-device override is
# strictly dryrun.py-local. Some tests spawn subprocesses that set their own
# XLA_FLAGS (multi-device pool tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
