import os
import sys

# Tests must see the real (single) CPU device — the 512-device override is
# strictly dryrun.py-local. Some tests spawn subprocesses that set their own
# XLA_FLAGS (multi-device pool tests).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is a declared test dependency (pyproject [test] extra), but
# hermetic containers may lack it — fall back to the deterministic shim so
# tests/test_properties.py still collects and runs.
sys.path.insert(0, os.path.dirname(__file__))
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _minihypothesis
    _minihypothesis.install()

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
