"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus writes JSON under
results/bench/). Each table runs in a subprocess because the SVFF pool
benches need their own forced device count (XLA locks it at first init).

  table1      paper Table I  — detach/attach vs pause/unpause cycle, 1/4/10
  table2      paper Table II — per-macro-step breakdown of one cycle
  throughput  paper claim §I(1) — step time before/after a pause cycle,
              + qdma_pack snapshot compression ratio
  roofline    §Roofline — aggregated dry-run table (40 cells x 2 meshes)
"""
import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "results", "bench")


def _sub(mod, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + ":" + ROOT
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-m", mod, *args],
                       capture_output=True, text=True, env=env,
                       timeout=7200)
    if p.returncode != 0:
        raise RuntimeError(f"{mod} failed:\n{p.stderr[-3000:]}")
    rows = []
    for line in p.stdout.splitlines():
        line = line.strip()
        if line.startswith("{"):
            rows.append(json.loads(line))
    return rows


def table1(runs: int = 30) -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = _sub("benchmarks.table1", "--runs", str(runs),
                "--out", os.path.join(OUT, "table1.json"))
    csv = []
    for r in rows:
        csv.append(("table1/detach_attach_%dvf" % r["num_vf"],
                    r["detach_attach_ms"] * 1000.0,
                    f"std_ms={r['detach_attach_std']:.1f}"))
        csv.append(("table1/pause_unpause_%dvf" % r["num_vf"],
                    r["pause_unpause_ms"] * 1000.0,
                    f"overhead_pct={r['overhead_pct']:.2f}"))
    return csv


def table2() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = _sub("benchmarks.table2",
                "--out", os.path.join(OUT, "table2.json"))
    csv = []
    for r in rows:
        for mode in ("DA", "PU"):
            for step in ("rescan", "remove_vf", "change_num_vf", "add_vf"):
                csv.append((f"table2/{mode}_{step}_{r['num_vf']}vf",
                            r[f"{mode}_{step}_ms"] * 1000.0,
                            f"total_ms={r[f'{mode}_total_ms']:.1f}"))
    return csv


def throughput() -> list:
    os.makedirs(OUT, exist_ok=True)
    rows = _sub("benchmarks.throughput",
                "--out", os.path.join(OUT, "throughput.json"))
    r = rows[0]
    return [
        ("throughput/step_before_pause", r["step_ms_before_pause"] * 1000,
         f"after_pct={r['pause_cycle_overhead_pct']:+.2f}"),
        ("throughput/step_after_unpause", r["step_ms_after_unpause"] * 1000,
         "native_perf_claim"),
        ("throughput/snapshot_none", r["snapshot_none_ms"] * 1000,
         f"bytes={r['snapshot_none_bytes']}"),
        ("throughput/snapshot_int8", r["snapshot_int8_ms"] * 1000,
         f"ratio={r['compression_ratio']:.2f}"),
    ]


def roofline() -> list:
    sys.path.insert(0, ROOT)
    from benchmarks.roofline_table import load_rows
    rows = load_rows()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    csv = []
    for r in rows:
        if r["status"] != "ok":
            continue
        csv.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    r["step_s"] * 1e6,
                    f"bound={r['bound']};mfu={r['mfu']*100:.1f}%"))
    return csv


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "throughput", "roofline"])
    ap.add_argument("--runs", type=int, default=30,
                    help="table1 cycle repetitions (paper: 100)")
    args = ap.parse_args()
    benches = {"table1": lambda: table1(args.runs), "table2": table2,
               "throughput": throughput, "roofline": roofline}
    names = [args.only] if args.only else list(benches)
    print("name,us_per_call,derived")
    for n in names:
        for row in benches[n]():
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)


if __name__ == "__main__":
    main()
