"""§Migration: request live migration under load — zero loss, bounded
stall, and scale-in-under-load at steady-state serving cadence.

The claims under test (see EXPERIMENTS.md §Migration):

  1. zero loss / token identity — a run that live-migrates in-flight
     requests between engines every few ticks completes every offered
     request with EXACTLY the token stream of an undisturbed run (the
     shipped KV block chain is bit-exact, the sampler counter-seeded);
  2. bounded stall — a migrating request's slot is frozen only while the
     synchronous hand-off runs, so the per-migration stall (decode ticks
     a frozen slot sat unservable) is bounded by ``STALL_BOUND``;
  3. scale-in under load — draining a BUSY engine by migrating its
     in-flight work (``ServeFleet.scale_in``) must not tax the requests
     that never migrated: their inter-token cadence stays within
     ``ITL_RATIO_TARGET`` x the steady-state p95.

Protocol: three runs over the SAME deterministic arrival schedule on a
two-engine paged fleet —

  steady    no interference (the baseline; also the token oracle)
  migrate   every ``--migrate-every`` ticks, one in-flight request
            live-migrates from the busier engine to the other
  scalein   at the trace midpoint, ``scale_in`` parks engine 1 while it
            is busy: queued work resubmits, active slots live-migrate,
            and the survivor serves everything to completion

Latency is measured in TICKS (fleet steps), the hardware-independent
measure used by the elastic sweep: one tick = one synchronized decode
iteration across engines. Wall-clock percentiles ride along as context.

Acceptance gates (committed BENCH_migration.json):
  * migrate run: 0 rejections, every request completes, and every
    token stream equals the steady run's (zero-loss + I10 across
    migration);
  * migrate run: stall_ticks / migrations_completed <= STALL_BOUND;
  * scalein run: >= 1 in-flight request actually migrated, and the
    non-migrated requests' itl_ticks_p95 <= ITL_RATIO_TARGET x the
    steady run's itl_ticks_p95.
CI reruns a reduced trace on PRs with the same gates.
"""
import argparse
import json
import sys
import tempfile
import time

STALL_BOUND = 2.0        # frozen-slot ticks tolerated per migration
ITL_RATIO_TARGET = 1.1   # non-migrated cadence vs steady-state p95


def pct(xs, q):
    from repro.serve import percentile
    return percentile(xs, q)


def make_request(rng, vocab, rid, max_new):
    from repro.serve import Request
    # fixed prompt length: one prefill executable per engine
    return Request(rid=rid, prompt=rng.integers(0, vocab, 8),
                   max_new_tokens=max_new)


def make_fleet(run, params, *, slots, slo_max_load):
    from repro.serve import ServeFleet
    return ServeFleet(run, params, num_engines=2, num_devices=4,
                      slots=slots, max_len=256, paged=True, page_size=16,
                      slo_max_load=slo_max_load,
                      workdir=tempfile.mkdtemp(prefix="svff_mig_"))


def warm_fleet(fleet, vocab, max_new):
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(99)
    for tn in fleet.tenants.values():
        tn.engine.submit(Request(rid=900_000 + fleet._order[tn.tid],
                                 prompt=rng.integers(0, vocab, 8),
                                 max_new_tokens=max(max_new, 24)))
        tn.engine.run_until_idle()


def drive(fleet, ticks, rng, vocab, *, max_new, arrive_every,
          migrate_every=0, scale_in_at=None, max_drain_ticks=2000):
    """One run. Returns (records, migrated_rids, rejected, wall_s).
    Arrivals depend only on the tick index, so every mode sees the same
    request at the same tick with the same prompt."""
    from repro.serve import RequestRejected
    live, finished, migrated = [], [], set()
    rejected = 0
    t0 = time.perf_counter()

    def poll(tick):
        for rec in list(live):
            r = rec["req"]
            if rec["first_tick"] is None and r.out:
                rec["first_tick"] = tick
            if r.done:
                rec["done_tick"] = tick
                rec["tokens"] = len(r.out)
                rec["out"] = list(r.out)
                finished.append(rec)
                live.remove(rec)

    def one_migration():
        running = sorted(
            (tn for tn in fleet.tenants.values()
             if tn.status == "running"),
            key=lambda tn: fleet._order[tn.tid])
        if len(running) < 2:
            return
        src = max(running,
                  key=lambda tn: (sum(r is not None
                                      for r in tn.engine.active),
                                  -fleet._order[tn.tid]))
        dst = next(tn for tn in running if tn.tid != src.tid)
        rid = src.peek_migratable()
        if rid is not None:
            if fleet.migrate_request(src.tid, dst.tid, rid) is not None:
                migrated.add(rid)

    tick = 0
    for tick in range(ticks):
        if tick % arrive_every == 0:
            r = make_request(rng, vocab, tick, max_new)
            r.t_submit = time.perf_counter()
            try:
                fleet.submit(r)
                live.append({"req": r, "submit_tick": tick,
                             "first_tick": None})
            except RequestRejected:
                rejected += 1
        if migrate_every and tick and tick % migrate_every == 0:
            one_migration()
        if scale_in_at is not None and tick == scale_in_at:
            victim = fleet.tenants["serve1"]
            # the in-flight slots about to live-migrate (queued work
            # moves for free and does not count as migrated)
            migrated |= {r.rid for r in victim.engine.active
                         if r is not None and not r.done}
            fleet.scale_in("serve1")
        fleet.step()
        poll(tick)
    while live and tick < ticks + max_drain_ticks:
        tick += 1
        fleet.step()
        poll(tick)
    assert not live, "trace left stranded work"
    res = fleet.drain()
    assert res.drained
    return finished, migrated, rejected, time.perf_counter() - t0


def row_for(name, recs, migrated, rejected, wall, fleet):
    def itl(rec):
        return ((rec["done_tick"] - rec["first_tick"])
                / max(rec["tokens"] - 1, 1))
    plain = [rec for rec in recs if rec["req"].rid not in migrated]
    moved = [rec for rec in recs if rec["req"].rid in migrated]
    stall = sum(tn.engine.stats["migration_stall_ticks"]
                for tn in fleet.tenants.values())
    desc = fleet.telemetry.describe()
    agg = {k: sum(d[k] for d in desc.values())
           for k in ("migrations_attempted", "migrations_completed",
                     "migrations_aborted", "migration_blocks")}
    return {"trace": name, "completed": len(recs), "rejected": rejected,
            "migrated_requests": len(moved),
            "itl_ticks_p95": round(pct([itl(r) for r in recs], 0.95), 3),
            "itl_ticks_p95_nonmigrated":
                round(pct([itl(r) for r in plain], 0.95), 3),
            "itl_ticks_p95_migrated":
                round(pct([itl(r) for r in moved], 0.95), 3),
            "ttft_ticks_p95": round(pct(
                [r["first_tick"] - r["submit_tick"] for r in recs],
                0.95), 3),
            "migration_stall_ticks": stall,
            "wall_s": round(wall, 3), **agg}


def bench(ticks=48, max_new=10, slots=8, slo_max_load=16,
          arrive_every=2, migrate_every=5, seed=0):
    import jax
    import numpy as np
    from repro.configs import make_run_config
    from repro.models.model import build_model

    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    vocab = run.model.vocab_size

    rows = [{"name": "protocol", "ticks": ticks, "max_new": max_new,
             "slots": slots, "slo_max_load": slo_max_load,
             "arrive_every": arrive_every,
             "migrate_every": migrate_every,
             "stall_bound": STALL_BOUND,
             "itl_ratio_target": ITL_RATIO_TARGET}]
    print(json.dumps(rows[0]))

    outs, by = {}, {}
    modes = (("steady", {}), ("migrate", {"migrate_every": migrate_every}),
             ("scalein", {"scale_in_at": ticks // 2}))
    for name, kw in modes:
        fleet = make_fleet(run, params, slots=slots,
                           slo_max_load=slo_max_load)
        warm_fleet(fleet, vocab, max_new)
        rng = np.random.default_rng(seed + 7)      # same prompts per tick
        recs, migrated, rejected, wall = drive(
            fleet, ticks, rng, vocab, max_new=max_new,
            arrive_every=arrive_every, **kw)
        row = row_for(name, recs, migrated, rejected, wall, fleet)
        rows.append(row)
        by[name] = row
        outs[name] = {rec["req"].rid: rec["out"] for rec in recs}
        print(json.dumps(row))

    steady_itl = by["steady"]["itl_ticks_p95"] or 1.0
    migs = max(by["migrate"]["migrations_completed"], 1)
    summary = {
        "name": "summary",
        "steady_itl_ticks_p95": steady_itl,
        "migrate_zero_loss": (
            by["migrate"]["rejected"] == 0
            and by["migrate"]["completed"] == by["steady"]["completed"]),
        "migrate_token_identical": outs["migrate"] == outs["steady"],
        "migrations_completed": by["migrate"]["migrations_completed"],
        "stall_ticks_per_migration": round(
            by["migrate"]["migration_stall_ticks"] / migs, 3),
        "stall_within_bound": (
            by["migrate"]["migration_stall_ticks"] / migs <= STALL_BOUND),
        "scalein_migrated_requests": by["scalein"]["migrated_requests"],
        "scalein_itl_ratio_nonmigrated": round(
            by["scalein"]["itl_ticks_p95_nonmigrated"] / steady_itl, 3),
    }
    summary["scalein_within_target"] = (
        by["scalein"]["migrated_requests"] >= 1
        and summary["scalein_itl_ratio_nonmigrated"] <= ITL_RATIO_TARGET)
    summary["all_gates"] = (
        summary["migrate_zero_loss"]
        and summary["migrate_token_identical"]
        and by["migrate"]["migrations_completed"] >= 1
        and summary["stall_within_bound"]
        and summary["scalein_within_target"])
    rows.append(summary)
    print(json.dumps(summary))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--slo-max-load", type=int, default=16)
    ap.add_argument("--arrive-every", type=int, default=2)
    ap.add_argument("--migrate-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(ticks=args.ticks, max_new=args.max_new,
                 slots=args.slots, slo_max_load=args.slo_max_load,
                 arrive_every=args.arrive_every,
                 migrate_every=args.migrate_every, seed=args.seed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if rows[-1]["all_gates"] else 1


if __name__ == "__main__":
    sys.exit(main())
