"""§Pipeline-serving: elastic K-VF pipeline engines — bit-identity at
every registered width, measured schedule bubble vs the GPipe analytic,
modeled tokens/s scaling with K, and the live-reshape stall.

The claims under test (see EXPERIMENTS.md §Pipeline-serving):

  1. bit-identity across K — ``PipelineServeEngine`` at every K in the
     template registry emits EXACTLY the single-stage oracle's token
     streams (the full-layout cache + forced unrolled-layer program make
     the stage split a pure relayout, invariant I10);
  2. measured bubble tracks the analytic — the per-(stage, microbatch)
     wall times fed through ``schedule_stats`` give a measured bubble
     fraction within ``BUBBLE_SLACK`` of ``bubble_fraction(M, K)``
     (uniform-wall GPipe: (K-1)/(M+K-1));
  3. tokens/s scales with K — per-stage walls on the smoke model are
     overhead-dominated, so throughput is MODELED for the full
     deepseek-67b layer stack (95 periods): with a balanced template,
     concurrent stage execution serves M microbatches per
     ``(M+K-1) * t_max_stage`` schedule round, a tokens/s ratio of
     ``(P / max_periods_per_stage) * M / (M+K-1)`` over one VF — the
     modeled column must increase strictly with K;
  4. bounded reshape stall — a live ``apply_reshape`` is a template
     re-selection over the SAME cache bytes: its wall time must be at
     most ``RESHAPE_STALL_RATIO`` of a cold engine re-instantiation
     (which re-jits every stage program), and the run it interrupts
     stays token-identical to the oracle.

Protocol: one oracle run (single-stage ``ServeEngine``, paged) over a
fixed request set, then one ``PipelineServeEngine`` run per K on the
SAME requests, then a live-reshape run that narrows K mid-decode.

Acceptance gates (committed BENCH_pipeline_serve.json):
  * token_identical at every K and across the live reshape;
  * measured_bubble <= bubble_fraction(M, K) + BUBBLE_SLACK per K;
  * modeled full-config tokens/s ratio strictly increasing in K;
  * reshape_wall_s <= RESHAPE_STALL_RATIO * cold_restart_s.
CI reruns a reduced trace on PRs with the same gates.
"""
import argparse
import dataclasses
import json
import sys
import time

BUBBLE_SLACK = 0.40          # measured vs analytic bubble, smoke walls
RESHAPE_STALL_RATIO = 0.5    # live reshape vs cold re-instantiation


def make_requests(vocab, n, max_new):
    import numpy as np
    from repro.serve import Request
    prompts = [np.arange(6) % vocab, (np.arange(8) * 3) % vocab,
               (np.arange(5) + 11) % vocab, (np.arange(7) * 7 + 2) % vocab]
    return [Request(rid=i, prompt=np.asarray(prompts[i % 4], np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def drive(eng, reqs, hook=None, max_steps=400):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not all(r.done for r in reqs):
        if hook:
            hook(steps)
        eng.step()
        steps += 1
        assert steps <= max_steps, "run did not converge"
    return [list(r.out) for r in reqs]


def modeled_scaling(num_periods, widths, microbatches):
    """Full-config modeled tokens/s ratio over one VF per width: balanced
    template, concurrent stages, per-period wall uniform."""
    from repro.serve.stages import build_templates
    tpls = build_templates(num_periods, max(widths))
    rows = {}
    for k in widths:
        tpl = tpls[k]
        longest = max(hi - lo for lo, hi in
                      (tpl.stage_range(i) for i in range(k)))
        rows[k] = round((num_periods / longest)
                        * microbatches / (microbatches + k - 1), 3)
    return rows


def bench(n_reqs=3, max_new=6, microbatches=2, widths=(2, 3, 4), seed=0):
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model
    from repro.runtime.pipeline import bubble_fraction
    from repro.serve import ServeEngine
    from repro.serve.pipeline_engine import PipelineServeEngine

    run = make_run_config("deepseek-67b", "decode_32k", smoke=True)
    # 4 periods so every width in 1..4 has a registered template; the
    # forced unrolled-layer program must match the pipeline engine's
    run = dataclasses.replace(
        run,
        model=dataclasses.replace(run.model, num_layers=4),
        sharding=dataclasses.replace(run.sharding, scan_layers=False))
    params = build_model(run).init(jax.random.key(seed))
    vocab = run.model.vocab_size
    full_periods = make_run_config("deepseek-67b", "decode_32k",
                                   smoke=False).model.num_layers

    rows = [{"name": "protocol", "model": "deepseek-67b (smoke, 4 layers)",
             "requests": n_reqs, "max_new": max_new,
             "microbatches": microbatches, "widths": list(widths),
             "modeled_periods": full_periods,
             "bubble_slack": BUBBLE_SLACK,
             "reshape_stall_ratio": RESHAPE_STALL_RATIO}]
    print(json.dumps(rows[0]))

    t0 = time.perf_counter()
    oracle = ServeEngine(run, params, slots=4, max_len=96, paged=True)
    want = drive(oracle, make_requests(vocab, n_reqs, max_new))
    oracle_row = {"name": "oracle_k1",
                  "tokens": sum(len(o) for o in want),
                  "wall_s": round(time.perf_counter() - t0, 3)}
    rows.append(oracle_row)
    print(json.dumps(oracle_row))

    per_k = {}
    for k in widths:
        t0 = time.perf_counter()
        eng = PipelineServeEngine(run, params, stages=k, slots=4,
                                  max_len=96, microbatches=microbatches)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        got = drive(eng, make_requests(vocab, n_reqs, max_new))
        analytic = bubble_fraction(microbatches, k)
        row = {"name": f"pipeline_k{k}", "stages": k,
               "token_identical": got == want,
               "sched_ticks": eng.sched_ticks,
               "measured_bubble": round(eng.measured_bubble, 3),
               "analytic_bubble": round(analytic, 3),
               "bubble_within_slack":
                   eng.measured_bubble <= analytic + BUBBLE_SLACK,
               "stage_loads": [round(x, 3) for x in eng.stage_loads()],
               "build_s": round(build_s, 3),
               "wall_s": round(time.perf_counter() - t0, 3)}
        per_k[k] = row
        rows.append(row)
        print(json.dumps(row))

    # live reshape: narrow the widest engine mid-decode, then measure a
    # cold re-instantiation at the target width for the stall comparison
    k_hi, k_lo = max(widths), max(widths) - 1
    eng = PipelineServeEngine(run, params, stages=k_hi, slots=4,
                              max_len=96, microbatches=microbatches)
    stall = {}

    def narrow(step):
        if step == 1:          # early: every trace length reaches it
            t0 = time.perf_counter()
            eng.apply_reshape(k_lo)
            stall["reshape_wall_s"] = time.perf_counter() - t0

    got = drive(eng, make_requests(vocab, n_reqs, max_new), hook=narrow)
    t0 = time.perf_counter()
    PipelineServeEngine(run, params, stages=k_lo, slots=4, max_len=96,
                        microbatches=microbatches)
    cold_s = time.perf_counter() - t0
    reshape_row = {
        "name": "live_reshape", "from_k": k_hi, "to_k": k_lo,
        "token_identical": got == want,
        "reshape_count": eng.reshape_count,
        "reshape_wall_s": round(stall["reshape_wall_s"], 6),
        "cold_restart_s": round(cold_s, 3),
        "stall_ratio": round(stall["reshape_wall_s"] / cold_s, 6)}
    rows.append(reshape_row)
    print(json.dumps(reshape_row))

    modeled = modeled_scaling(full_periods, (1,) + tuple(widths),
                              max(microbatches, 4))
    model_row = {"name": "modeled_full_config",
                 "periods": full_periods,
                 "microbatches": max(microbatches, 4),
                 "tokens_per_s_ratio": {str(k): v
                                        for k, v in modeled.items()}}
    rows.append(model_row)
    print(json.dumps(model_row))

    ratios = [modeled[k] for k in sorted(modeled)]
    summary = {
        "name": "summary",
        "token_identical_all_k": all(per_k[k]["token_identical"]
                                     for k in widths),
        "bubble_within_slack_all_k": all(per_k[k]["bubble_within_slack"]
                                         for k in widths),
        "modeled_scaling_monotonic": all(a < b for a, b in
                                         zip(ratios, ratios[1:])),
        "reshape_token_identical": reshape_row["token_identical"],
        "reshape_stall_ratio": reshape_row["stall_ratio"],
        "reshape_stall_bounded":
            reshape_row["stall_ratio"] <= RESHAPE_STALL_RATIO}
    summary["all_gates"] = (
        summary["token_identical_all_k"]
        and summary["bubble_within_slack_all_k"]
        and summary["modeled_scaling_monotonic"]
        and summary["reshape_token_identical"]
        and summary["reshape_stall_bounded"])
    rows.append(summary)
    print(json.dumps(summary))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reqs", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--widths", type=int, nargs="+", default=[2, 3, 4])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(n_reqs=args.reqs, max_new=args.max_new,
                 microbatches=args.microbatches,
                 widths=tuple(args.widths), seed=args.seed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if rows[-1]["all_gates"] else 1


if __name__ == "__main__":
    sys.exit(main())
