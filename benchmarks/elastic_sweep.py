"""§Elastic: static vs autoscaler-driven fleets under shaped traffic.

The claim under test: closing the loop from observed load back into VF
reconfiguration (scale-out / scale-in / rebalance through the journaled
manager ops) beats a static fleet on SLO-miss rate and rejection rate
under non-stationary traffic, without taxing inter-token latency.

Protocol (see EXPERIMENTS.md §Elastic): one STATIC fleet (1 engine, no
control plane) and one ELASTIC fleet (1 engine + 3 warm parked standbys
on pre-carved spare VFs, ``AutoscaleConfig(max_engines=4)``) serve the
same four traffic traces —

  steady    constant light load (the baseline; both fleets cope)
  ramp      arrivals grow linearly 0 -> ~3x one engine's service rate
  spike     light baseline with a short burst of ~3x slo_max_load
  diurnal   one sinusoid period, peak ~2.5x one engine's service rate

— one request wave + one fleet step per tick; the elastic fleet runs one
``autoscale_step`` per tick. Rejected requests are dropped and counted.

Latency is measured in TICKS (fleet steps), not wall time: on real
hardware every VF's engine steps in parallel on its own devices, whereas
this host steps them sequentially, so wall time would charge scale-out
for concurrency the hardware provides for free. Tick-space is the
hardware-independent measure (the same convention the pause-path
hillclimb uses for the zero-copy CPU grid); wall-clock percentiles are
still reported per row as context. SLOs: first token within
``SLO_TTFT_TICKS`` of submission, mean inter-token gap <=
``SLO_ITL_TICKS``. A rejected request counts as an SLO miss (it got no
conformant service), so shedding load cannot fake a good miss rate.

Acceptance gates (committed BENCH_elastic.json):
  * spike & ramp: elastic slo_miss_rate AND rejection_rate strictly
    below static;
  * every elastic trace's itl_ticks_p95 <= 1.1x the static steady-state
    itl_ticks_p95 (elasticity must not tax serving cadence).
CI reruns a reduced trace on PRs with the same gates.
"""
import argparse
import json
import math
import sys
import time

SLO_TTFT_TICKS = 4       # first token within ~half a slot-generation
SLO_ITL_TICKS = 1.5      # sustained decode cadence: ~a token per tick


def make_traces(ticks: int, peak: int) -> dict:
    """Per-tick arrival counts, deterministic."""
    third = max(1, ticks // 3)
    return {
        "steady": [1 if t % 2 == 0 else 0 for t in range(ticks)],
        "ramp": [round(peak * t / (ticks - 1)) for t in range(ticks)],
        "spike": [1 if t % 2 == 0 else 0 for t in range(ticks)][:third]
                 + [peak * 4] * 2
                 + [1 if t % 2 == 0 else 0
                    for t in range(ticks - third - 2)],
        "diurnal": [round(peak * 0.8 * (0.5 - 0.5 * math.cos(
            2 * math.pi * t / (ticks - 1)))) for t in range(ticks)],
    }


def pct(xs, q):
    from repro.serve import percentile
    return percentile(xs, q)


class _Rids:
    def __init__(self):
        self.n = 0

    def take(self):
        self.n += 1
        return self.n


def make_request(rng, vocab, rid, max_new):
    from repro.serve import Request
    # fixed prompt length: ONE prefill executable per engine, so warming
    # stays cheap even with 4 engines x 2 fleets
    return Request(rid=rid, prompt=rng.integers(0, vocab, 8),
                   max_new_tokens=max_new)


def warm_fleet(fleet, vocab, max_new):
    """Compile every executable each engine (attached AND parked) will
    need: one prefill at the fixed prompt length + one decode crossing a
    page boundary."""
    from repro.serve import Request
    import numpy as np
    rng = np.random.default_rng(99)
    for tn in fleet.tenants.values():
        eng = tn.engine
        eng.submit(Request(rid=900_000 + fleet._order[tn.tid],
                           prompt=rng.integers(0, vocab, 8),
                           max_new_tokens=max(max_new, 24)))
        eng.unpause()
        eng.run_until_idle()


def drive(fleet, trace, rng, vocab, rids, *, max_new, elastic,
          max_drain_ticks=2000):
    """Run one trace; returns per-request tick/wall stats. The tick
    counter keeps advancing through the post-trace drain, so queue debt
    built during the trace is paid on the record."""
    from repro.serve import RequestRejected
    live, finished, actions = [], [], []
    offered = rejected = 0
    t0 = time.perf_counter()

    def poll(tick):
        for rec in list(live):
            r = rec["req"]
            if rec["first_tick"] is None and r.out:
                rec["first_tick"] = tick
            if r.done:
                rec["done_tick"] = tick
                rec["tokens"] = len(r.out)
                finished.append(rec)
                live.remove(rec)

    tick = 0
    for tick, n in enumerate(trace):
        for _ in range(n):
            r = make_request(rng, vocab, rids.take(), max_new)
            offered += 1
            r.t_submit = time.perf_counter()
            try:
                fleet.submit(r)
                live.append({"req": r, "submit_tick": tick,
                             "first_tick": None})
            except RequestRejected:
                rejected += 1          # dropped: the caller's retry policy
        if elastic:
            act = fleet.autoscale_step()
            if act is not None:
                actions.append({"tick": tick, "kind": act.kind,
                                "reason": act.reason})
        fleet.step()
        poll(tick)
    while live and tick < len(trace) + max_drain_ticks:
        tick += 1
        if elastic:
            act = fleet.autoscale_step()
            if act is not None:
                actions.append({"tick": tick, "kind": act.kind,
                                "reason": act.reason})
        fleet.step()
        poll(tick)
    assert not live, "trace left stranded work"
    res = fleet.drain()
    assert res.drained
    return finished, offered, rejected, actions, time.perf_counter() - t0


def row_for(name, mode, recs, offered, rejected, wall, actions):
    ttft_t = [rec["first_tick"] - rec["submit_tick"] for rec in recs]
    itl_t = [(rec["done_tick"] - rec["first_tick"])
             / max(rec["tokens"] - 1, 1) for rec in recs]
    ttft_w, itl_w = [], []
    for rec in recs:
        r = rec["req"]
        if r.t_tok:
            ttft_w.append(r.t_tok[0] - r.t_submit)
            itl_w.extend(b - a for a, b in zip(r.t_tok, r.t_tok[1:]))
    # SLO accounting over the OFFERED load: rejected = missed
    miss = rejected + sum(
        1 for tt, it in zip(ttft_t, itl_t)
        if tt > SLO_TTFT_TICKS or it > SLO_ITL_TICKS)
    return {"trace": name, "mode": mode, "offered": offered,
            "completed": len(recs), "rejected": rejected,
            "rejection_rate": round(rejected / max(offered, 1), 4),
            "slo_miss_rate": round(miss / max(offered, 1), 4),
            "ttft_ticks_p50": pct(ttft_t, 0.5),
            "ttft_ticks_p95": pct(ttft_t, 0.95),
            "itl_ticks_p50": round(pct(itl_t, 0.5), 3),
            "itl_ticks_p95": round(pct(itl_t, 0.95), 3),
            "ttft_p95_ms": round(pct(ttft_w, 0.95) * 1e3, 3),
            "itl_p95_ms": round(pct(itl_w, 0.95) * 1e3, 3),
            "wall_s": round(wall, 3), "actions": actions}


def reset_elastic(fleet, min_engines):
    """Between traces: park extra engines and forget control-plane state,
    so each trace starts from the same 1-engine fleet."""
    from repro.core.autoscaler import Autoscaler
    from repro.serve.telemetry import MetricsBus
    running = sorted(
        (tn for tn in fleet.tenants.values() if tn.status == "running"),
        key=lambda tn: fleet._order[tn.tid])
    for tn in running[min_engines:]:
        fleet.scale_in(tn.tid)
    if fleet.autoscaler is not None:
        fleet.autoscaler = Autoscaler(fleet.autoscale_config)
    fleet.telemetry = MetricsBus()
    fleet.rejections.clear()
    fleet.rejected_total = 0


def bench(ticks=60, peak=3, max_new=8, slots=8, slo_max_load=16,
          seed=0):
    import tempfile
    import jax
    import numpy as np
    from repro.configs import make_run_config
    from repro.core.autoscaler import AutoscaleConfig
    from repro.models.model import build_model
    from repro.serve import ServeFleet

    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    vocab = run.model.vocab_size
    kw = dict(num_devices=8, slots=slots, max_len=256, paged=True,
              page_size=16, slo_max_load=slo_max_load)
    static = ServeFleet(run, params, num_engines=1,
                        workdir=tempfile.mkdtemp(prefix="svff_el_s_"),
                        **kw)
    # 3 warm standbys + 3 pre-carved spare VFs: scale-out is a pause-free
    # attach (the reconf grow path stays covered by tests); a lower hot
    # threshold + short cooldown reacts within ~2 ticks of a burst
    elastic = ServeFleet(run, params, num_engines=1, spare_engines=3,
                         num_vfs=4,
                         autoscale=AutoscaleConfig(
                             scale_out_load=0.5, hysteresis=1, cooldown=1,
                             rebalance_gap=6, max_engines=4,
                             min_engines=1, rebalance_migrate=False),
                         workdir=tempfile.mkdtemp(prefix="svff_el_e_"),
                         **kw)
    warm_fleet(static, vocab, max_new)
    warm_fleet(elastic, vocab, max_new)

    rows = [{"name": "protocol", "ticks": ticks, "peak_per_tick": peak,
             "max_new": max_new, "slots": slots,
             "slo_max_load": slo_max_load,
             "slo_ttft_ticks": SLO_TTFT_TICKS,
             "slo_itl_ticks": SLO_ITL_TICKS}]
    print(json.dumps(rows[0]))

    rids = _Rids()
    traces = make_traces(ticks, peak)
    by = {}
    for name, trace in traces.items():
        for mode, fleet in (("static", static), ("elastic", elastic)):
            rng = np.random.default_rng(seed + 7)   # same arrivals
            recs, offered, rejected, actions, wall = drive(
                fleet, trace, rng, vocab, rids, max_new=max_new,
                elastic=(mode == "elastic"))
            row = row_for(name, mode, recs, offered, rejected, wall,
                          actions)
            rows.append(row)
            by[(name, mode)] = row
            print(json.dumps(row))
            if mode == "elastic":
                reset_elastic(fleet, 1)

    # guard ONLY the degenerate no-sample case (p95 == 0.0); a real
    # sub-1.0 steady p95 must stay the gate's denominator, or the 1.1x
    # target would be silently loosened
    st = by[("steady", "static")]["itl_ticks_p95"]
    steady_itl = st if st > 0 else 1.0
    summary = {"name": "summary",
               "static_steady_itl_ticks_p95": steady_itl,
               "itl_ratio_target": 1.1}
    gates = []
    for name in ("spike", "ramp"):
        s, e = by[(name, "static")], by[(name, "elastic")]
        summary[f"{name}_rejection_static"] = s["rejection_rate"]
        summary[f"{name}_rejection_elastic"] = e["rejection_rate"]
        summary[f"{name}_slo_miss_static"] = s["slo_miss_rate"]
        summary[f"{name}_slo_miss_elastic"] = e["slo_miss_rate"]
        gates.append(e["rejection_rate"] < s["rejection_rate"])
        gates.append(e["slo_miss_rate"] < s["slo_miss_rate"])
    ratios = {name: round(by[(name, "elastic")]["itl_ticks_p95"]
                          / steady_itl, 3)
              for name in traces}
    summary["elastic_itl_ticks_p95_vs_static_steady"] = ratios
    summary["actions_per_trace"] = {
        name: [a["kind"] for a in by[(name, "elastic")]["actions"]]
        for name in traces}
    summary["elastic_beats_static_spike_ramp"] = all(gates)
    summary["itl_within_target"] = (
        max(ratios.values()) <= summary["itl_ratio_target"])
    rows.append(summary)
    print(json.dumps(summary))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=60)
    ap.add_argument("--peak", type=int, default=3,
                    help="requests/tick at the ramp's end")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--slo-max-load", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(ticks=args.ticks, peak=args.peak, max_new=args.max_new,
                 slots=args.slots, slo_max_load=args.slo_max_load,
                 seed=args.seed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    summary = rows[-1]
    ok = (summary["elastic_beats_static_spike_ramp"]
          and summary["itl_within_target"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
