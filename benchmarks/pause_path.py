"""§Perf: hillclimbing the pause/unpause path itself (the paper's own
metric, Table I). Iterations — see EXPERIMENTS.md §Perf for the protocol:

  HC1  transfer-queue count (the QDMA queue analogue): 1/2/4/8/16 streams
       round-robining WHOLE leaves (the PR-1 engine, pipeline=False);
       queues_8 is the baseline every later iteration must beat
  HC2  qdma_pack int8 compression of the snapshot payload (lossy — bytes
       vs error trade; intended for serving tenants / tolerant restarts)
  HC3  incremental snapshots: identical (immutable) device arrays are not
       re-transferred — a serving tenant's params never change between
       pauses, only its KV cache does
  HC4  pipelined descriptor engine: fixed-size row-chunk descriptors over
       burst-batched transfer queues with an overlapped pack->D2H->
       assemble pipeline (borrow transport on host-device grids; the
       stream row shows the explicit chunked path)
  HC5  pre-copy live pause: background snapshot rounds while the tenant
       keeps stepping, then a stop-and-copy of only the dirtied leaves —
       tenant-visible stall (stop_ms) vs the stop-the-world pause total

Measured on a realistic ~400MB-params state (qwen3-100m-class params +
adam moments, ~900MB total) on the forced 8-device CPU host grid.
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import sys
import time


def bench(repeats: int = 3) -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import register
    from repro.core import StagingEngine
    import repro.configs.base as B
    from repro.train.step import init_train_state
    from repro.configs import make_run_config

    def qwen3_100m():
        return B.ModelConfig(
            name="qwen3-100m-bench", family="dense",
            num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
            d_ff=1920, vocab_size=32000, head_dim=64, qk_norm=True,
            tie_embeddings=True)

    register("qwen3-100m-bench", qwen3_100m, qwen3_100m)
    run = make_run_config("qwen3-100m-bench", "train_4k")
    state = init_train_state(run, jax.random.key(0))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
    rows = []

    def timeit(name, eng, tree, note="", save_stat="median"):
        """save_stat='first' reports the FIRST save: for memo-bearing
        rows whose later repeats identity-hit everything, the median
        would time the all-skip path instead of the labeled workload."""
        saves, restores = [], []
        moved = descriptors = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            staged = eng.save(tree)
            saves.append(time.perf_counter() - t0)
            if moved is None:           # first save (memo cold)
                moved = eng.last_stats.bytes_moved
                descriptors = eng.last_stats.num_descriptors
            t0 = time.perf_counter()
            out = eng.restore(staged)
            jax.block_until_ready(out)
            restores.append(time.perf_counter() - t0)
        err = 0.0
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            if np.issubdtype(np.asarray(b).dtype, np.floating):
                d = np.abs(np.asarray(a, np.float32) -
                           np.asarray(b, np.float32))
                s = np.abs(np.asarray(a, np.float32)).max() + 1e-9
                err = max(err, float(d.max() / s))
        save_ms = (saves[0] if save_stat == "first"
                   else statistics.median(saves)) * 1000
        restore_ms = statistics.median(restores) * 1000
        rows.append({"name": name, "save_ms": save_ms,
                     "restore_ms": restore_ms,
                     "save_plus_restore_ms": save_ms + restore_ms,
                     "bytes_moved": int(moved), "logical_bytes": int(nbytes),
                     "descriptors": descriptors,
                     "max_rel_err": err, "note": note})
        return rows[-1]

    # HC1: queue sweep (uncompressed, PR-1 whole-leaf round-robin engine)
    for q in (1, 2, 4, 8, 16):
        timeit(f"queues_{q}", StagingEngine(num_queues=q, pipeline=False),
               state, note="PR-1 baseline engine" if q == 8 else "")

    # HC2: int8 compression (block=128 divides every trailing dim here)
    timeit("int8", StagingEngine(num_queues=8, compression="int8",
                                 block=128, pipeline=False), state,
           note="lossy: bounded by one quant step (see test_properties)")

    # HC3: incremental — second save of an UNCHANGED tree moves ~0 bytes
    eng = StagingEngine(num_queues=8, incremental=True, pipeline=False)
    eng.save(state)                              # warm the memo
    timeit("incremental_unchanged", eng, state, note="params identical")
    # and a half-changed tree (simulates serving: cache moves, params don't)
    state2 = dict(state)
    state2["opt"] = state["opt"]                 # same objects
    state2["params"] = jax.tree.map(lambda x: x * 1.0, state["params"])
    timeit("incremental_half_changed", eng, state2,
           note="params changed, opt identical", save_stat="first")

    # HC4: pipelined descriptor engine (chunk descriptors, burst queues,
    # overlapped pack->D2H->assemble; borrow transport on this CPU grid)
    timeit("pipelined", StagingEngine(num_queues=8), state,
           note="descriptor engine, auto transport")
    timeit("pipelined_stream",
           StagingEngine(num_queues=8, transport="stream",
                         chunk_bytes=16 << 20), state,
           note="explicit chunked streaming (accelerator-shaped path)")
    timeit("int8_pipelined",
           StagingEngine(num_queues=8, compression="int8", block=128),
           state, note="chunk-granular pack overlapped with D2H")

    # HC5: pre-copy live pause vs stop-the-world, serving-shaped tenant
    rows.extend(_bench_live_pause(jax, jnp, state, repeats))
    return rows


def _bench_live_pause(jax, jnp, state, repeats: int) -> list:
    """Stop-the-world pause_vf vs pause_vf_live on a tenant whose params
    (~the full bench state) are clean and only a small KV cache is hot."""
    import numpy as np
    from repro.core import (DevicePool, StagingEngine, pause_vf,
                            pause_vf_live, unpause_vf)
    from repro.core.vf import VFState, VirtualFunction
    from repro.sim import ServeSimTenant

    def mk_tenant(tid):
        params = jax.tree.map(lambda x: x + 0, state)   # private copy
        cache = jnp.zeros((64, 1024), jnp.float32)      # ~256KB hot state
        jax.block_until_ready((params, cache))   # don't time the copy
        return ServeSimTenant(params, cache, tid=tid)

    def mk_vf(vid):
        vf = VirtualFunction(vf_id=vid)
        vf.assign_devices(jax.devices()[:1], (1, 1))
        vf.transition(VFState.ATTACHED)
        return vf

    pool = DevicePool(devices=jax.devices())
    out = []

    def run_one(name, live):
        totals, stops = [], []
        for r in range(repeats + 1):     # first iteration = warmup, dropped
            tn = mk_tenant(f"{name}{r}")
            vf = mk_vf(f"0000:0b:00.{r}")
            vf.owner = tn.tid
            tn.vf_id = vf.vf_id
            staging = StagingEngine(num_queues=8, incremental=True)
            for _ in range(4):
                tn.step()                        # steady-state serving
            if live:
                snap, t = pause_vf_live(pool, vf, tn, staging, rounds=2,
                                        step_fn=tn.step)
            else:
                snap, t = pause_vf(pool, vf, tn, staging)
            if r > 0:
                totals.append(t.total * 1e3)
                stops.append(t.stop_ms)
            # restore so the copies don't pile up in device memory
            vf.assign_devices(jax.devices()[:1], (1, 1))
            unpause_vf(pool, vf, tn, snap, staging)
            tn.params = None
            tn.cache = None
        import statistics as st
        return {"name": name, "total_ms": st.median(totals),
                "stop_ms": st.median(stops)}

    world = run_one("pause_stop_world", live=False)
    world["note"] = "tenant stalled for the whole save"
    live = run_one("pause_live_precopy", live=True)
    live["stop_speedup_vs_stop_world"] = (
        world["total_ms"] / max(live["stop_ms"], 1e-9))
    live["note"] = ("pre-copy rounds in background; stop-and-copy moves "
                    "only the dirty cache")
    return [world, live]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(args.repeats)
    for r in rows:
        print(json.dumps(r))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
