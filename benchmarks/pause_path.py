"""§Perf HC3: hillclimbing the pause/unpause path itself (the paper's own
metric, Table I). Iterations:

  it.1  transfer-queue count (the QDMA queue analogue): 1/2/4/8/16 streams
  it.2  qdma_pack int8 compression of the snapshot payload (lossy — bytes
        vs error trade; intended for serving tenants / tolerant restarts)
  it.3  incremental snapshots: identical (immutable) device arrays are not
        re-transferred — a serving tenant's params never change between
        pauses, only its KV cache does

Measured on a realistic ~400MB state (qwen3-100m-class params + adam).
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import sys
import time


def bench(repeats: int = 3) -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import register
    from repro.core import StagingEngine
    import repro.configs.base as B
    from repro.train.step import init_train_state
    from repro.configs import make_run_config

    def qwen3_100m():
        return B.ModelConfig(
            name="qwen3-100m-bench", family="dense",
            num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
            d_ff=1920, vocab_size=32000, head_dim=64, qk_norm=True,
            tie_embeddings=True)

    register("qwen3-100m-bench", qwen3_100m, qwen3_100m)
    run = make_run_config("qwen3-100m-bench", "train_4k")
    state = init_train_state(run, jax.random.key(0))
    nbytes = sum(x.nbytes for x in jax.tree.leaves(state))
    rows = []

    def timeit(name, eng, tree, note=""):
        ts = []
        moved = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            staged = eng.save(tree)
            ts.append(time.perf_counter() - t0)
            if moved is None:           # first save (memo cold)
                moved = eng.last_stats.bytes_moved
        t0 = time.perf_counter()
        out = eng.restore(staged)
        restore_s = time.perf_counter() - t0
        err = 0.0
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            if np.issubdtype(np.asarray(b).dtype, np.floating):
                d = np.abs(np.asarray(a, np.float32) -
                           np.asarray(b, np.float32))
                s = np.abs(np.asarray(a, np.float32)).max() + 1e-9
                err = max(err, float(d.max() / s))
        rows.append({"name": name, "save_ms": statistics.median(ts) * 1000,
                     "restore_ms": restore_s * 1000,
                     "bytes_moved": int(moved), "logical_bytes": int(nbytes),
                     "max_rel_err": err, "note": note})

    # it.1: queue sweep (uncompressed)
    for q in (1, 2, 4, 8, 16):
        timeit(f"queues_{q}", StagingEngine(num_queues=q), state)

    # it.2: int8 compression (block=128 divides every trailing dim here)
    timeit("int8", StagingEngine(num_queues=8, compression="int8",
                                 block=128), state,
           note="lossy: bounded by one quant step (see test_properties)")

    # it.3: incremental — second save of an UNCHANGED tree moves ~0 bytes
    eng = StagingEngine(num_queues=8, incremental=True)
    eng.save(state)                              # warm the memo
    timeit("incremental_unchanged", eng, state, note="params identical")
    # and a half-changed tree (simulates serving: cache moves, params don't)
    state2 = dict(state)
    state2["opt"] = jax.tree.map(lambda x: x + 0 if False else x,
                                 state["opt"])   # same objects
    state2["params"] = jax.tree.map(lambda x: x * 1.0, state["params"])
    timeit("incremental_half_changed", eng, state2,
           note="params changed, opt identical")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(args.repeats)
    for r in rows:
        print(json.dumps(r))
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
