"""Paper claim (1): 'native performance ... no performance loss' — a
pause/unpause cycle must not change the tenant's steady-state step time
(the guest driver never reloads, executables stay cached). Also measures
the staging engine's snapshot bandwidth with and without qdma_pack int8
compression (the beyond-paper pause-path optimization)."""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import sys


def bench(steps: int = 20) -> dict:
    import tempfile
    import jax  # noqa: F401
    from repro.configs import make_run_config
    from repro.core import DevicePool, SVFFManager, StagingEngine, Tenant

    run = make_run_config("svff-bench", "train_4k", smoke=True)
    pool = DevicePool()
    mgr = SVFFManager(pool, workdir=tempfile.mkdtemp(prefix="svff_tp_"))
    tn = Tenant("vm0", run, local_batch=4, seq_len=64)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=4)
    tn.run_steps(5)                         # warmup
    tn.step_times.clear()
    tn.run_steps(steps)
    before = statistics.median(tn.step_times)

    mgr.pause(tn)
    mgr.unpause(tn)
    tn.run_steps(2)
    tn.step_times.clear()
    tn.run_steps(steps)
    after = statistics.median(tn.step_times)

    out = {"step_ms_before_pause": before * 1000,
           "step_ms_after_unpause": after * 1000,
           "pause_cycle_overhead_pct": 100 * (after - before) / before}

    # snapshot bandwidth, plain vs qdma_pack int8
    state = tn.export_state()
    for comp in ("none", "int8"):
        eng = StagingEngine(compression=comp, min_quant_size=1024)
        staged = eng.save(state)
        st = eng.last_stats
        out[f"snapshot_{comp}_bytes"] = st.bytes_moved
        out[f"snapshot_{comp}_ms"] = st.seconds * 1000
        out[f"snapshot_{comp}_gbps"] = st.bandwidth_gbps
    out["compression_ratio"] = (out["snapshot_none_bytes"] /
                                out["snapshot_int8_bytes"])
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    row = bench(args.steps)
    print(json.dumps(row))
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(row, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
