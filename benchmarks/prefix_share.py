"""§Prefix-sharing: effective cache capacity under a shared-prefix trace.

The trace models system-prompt traffic: every request opens with the same
96-token system prefix (6 full pages at page_size 16) and closes with a
short per-pair tail — pairs share their tail too, so the trace exercises
full-page sharing, partial-page sharing, AND the copy-on-write splits
that fire when paired requests start decoding into their shared partial
page.

Rows (see EXPERIMENTS.md §Prefix-sharing for the protocol):

  copy_on_admit        today's baseline: every admission copies its full
                       KV into private pages — N residents on one system
                       prompt burn N copies of its pages
  prefix_share         the refcounted trie + CoW path (share_prefix=True):
                       residents map their block tables onto the same
                       physical prefix pages; divergence splits exactly
                       one page per writer

The headline metric is ``peak_pages_at_full_residency``: pool pages in
use while ALL slots are resident — the same resident concurrency, so the
ratio is the effective-capacity multiplier. The acceptance gates are
deterministic (page accounting + token identity), so they hold unchanged
on noisy shared runners:

  capacity_ratio >= 3.0     (acceptance: >= 3x effective cache capacity)
  outputs bit-identical     (per-token, sharing vs copy-on-admit — I10)
"""
import argparse
import json
import sys
import time


def make_trace(n, vocab, prefix_len=96, tail_len=4, max_new=8, seed=0):
    """n requests: one shared system prefix + per-PAIR unique tails (pair
    members are identical end-to-end, so their shared partial page must
    CoW-split when they decode)."""
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len)
    reqs = []
    for i in range(n):
        tail = np.asarray([(17 * (i // 2) + 3 + j) % vocab
                           for j in range(tail_len)])
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=max_new))
    return reqs


def run_trace(run, params, reqs, *, share, slots, page_size, max_len,
              num_pages):
    """Serve the trace to completion, sampling pool pages in use at every
    step; returns (wall_s, peak pages while ALL slots were resident,
    engine stats)."""
    from repro.serve import Request, ServeEngine
    import numpy as np
    eng = ServeEngine(run, params, slots=slots, max_len=max_len,
                      paged=True, page_size=page_size,
                      num_pages=num_pages, share_prefix=share)
    # warm the executables (same prompt length / decode width as the
    # trace) so compile time doesn't pollute the wall clock
    warm = Request(rid=9_999,
                   prompt=np.asarray(reqs[0].prompt).copy(),
                   max_new_tokens=reqs[0].max_new_tokens)
    eng.submit(warm)
    eng.run_until_idle()
    t0 = time.perf_counter()
    for r in reqs:
        r.t_submit = time.perf_counter()
        eng.queue.append(r)
    peak_full = 0
    steps = 0
    while (eng.step() or eng.queue) and steps < 10_000:
        resident = sum(r is not None for r in eng.active)
        if resident == slots:
            peak_full = max(peak_full, eng.alloc.pages_in_use)
        steps += 1
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    assert eng.alloc.pages_in_use == 0
    eng.alloc.check_invariants()
    assert peak_full > 0, "trace never reached full residency"
    return wall, peak_full, dict(eng.stats)


def bench(requests=8, slots=8, prefix_len=96, tail_len=4, max_new=8,
          page_size=16, max_len=128, num_pages=64):
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model

    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    vocab = run.model.vocab_size
    rows = []
    outs = {}

    for name, share in (("copy_on_admit", False), ("prefix_share", True)):
        reqs = make_trace(requests, vocab, prefix_len=prefix_len,
                          tail_len=tail_len, max_new=max_new)
        wall, peak, stats = run_trace(run, params, reqs, share=share,
                                      slots=slots, page_size=page_size,
                                      max_len=max_len,
                                      num_pages=num_pages)
        toks = sum(len(r.out) for r in reqs)
        outs[name] = [list(r.out) for r in reqs]
        row = {"name": name, "requests": len(reqs),
               "resident_slots": slots,
               "generated_tokens": toks,
               "wall_s": round(wall, 4),
               "tokens_per_s": round(toks / wall, 2),
               "peak_pages_at_full_residency": peak,
               "shared_page_hits": stats.get("shared_page_hits", 0),
               "cow_splits": stats.get("cow_splits", 0),
               "note": (f"prefix={prefix_len} tail={tail_len} "
                        f"page={page_size} pool={num_pages}p")}
        rows.append(row)
        print(json.dumps(row))

    base = rows[0]["peak_pages_at_full_residency"]
    shared = rows[1]["peak_pages_at_full_residency"]
    summary = {"name": "summary",
               "capacity_ratio": round(base / shared, 3),
               "capacity_ratio_target": 3.0,
               "outputs_bit_identical":
                   outs["copy_on_admit"] == outs["prefix_share"],
               "cow_splits": rows[1]["cow_splits"],
               "shared_page_hits": rows[1]["shared_page_hits"]}
    rows.append(summary)
    print(json.dumps(summary))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=96)
    ap.add_argument("--tail-len", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(requests=args.requests, slots=args.slots,
                 prefix_len=args.prefix_len, tail_len=args.tail_len,
                 max_new=args.max_new, page_size=args.page_size,
                 max_len=args.max_len, num_pages=args.num_pages)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    summary = rows[-1]
    # both gates are deterministic (page accounting + token identity), so
    # they are the acceptance numbers, not relaxed CI floors
    ok = (summary["capacity_ratio"] >= summary["capacity_ratio_target"]
          and summary["outputs_bit_identical"]
          and summary["cow_splits"] >= 1)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
