"""Scenario sweep: throughput + reconf latency of the management plane,
per placement policy, over many seeded randomized scenarios.

Runs N deterministic scenarios (repro.sim) per policy against the real
SVFFManager stack (simulated device tokens, SimTenant workloads) and
reports, as JSON:

  ops/sec               management-op throughput (wall clock)
  reconf p50/p95 (ms)   percentiles of the Table-II `total` across every
                        reconfiguration cycle executed in the sweep
  rejected              chaos-op rejections (all atomic, invariant-checked)

Usage:
  PYTHONPATH=src python benchmarks/scenario_sweep.py --scenarios 1000 \
      --out results/scenario_sweep.json
"""
import argparse
import json
import os
import sys
import time

import numpy as np


def sweep(policies, scenarios: int, num_ops: int, num_devices: int,
          seed0: int = 0) -> dict:
    from repro.sim import ScenarioConfig, ScenarioRunner

    report = {"config": {"scenarios_per_policy": scenarios,
                         "num_ops": num_ops, "num_devices": num_devices,
                         "seed0": seed0},
              "policies": {}}
    for policy in policies:
        ops = ok = rejected = 0
        reconf_ms = []
        t0 = time.perf_counter()
        for i in range(scenarios):
            res = ScenarioRunner(ScenarioConfig(
                seed=seed0 + i, policy=policy, num_ops=num_ops,
                num_devices=num_devices)).run()
            ops += len(res.ops)
            ok += res.num_ok
            rejected += res.num_rejected
            reconf_ms += [t["total"] * 1e3 for t in res.reconf_timings]
        wall = time.perf_counter() - t0
        report["policies"][policy] = {
            "scenarios": scenarios,
            "ops": ops,
            "ops_ok": ok,
            "rejected": rejected,
            "wall_s": wall,
            "ops_per_sec": ops / wall,
            "reconfs": len(reconf_ms),
            "reconf_p50_ms": (float(np.percentile(reconf_ms, 50))
                              if reconf_ms else None),
            "reconf_p95_ms": (float(np.percentile(reconf_ms, 95))
                              if reconf_ms else None),
        }
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios", type=int, default=1000,
                    help="scenarios per policy")
    ap.add_argument("--ops", type=int, default=24, help="ops per scenario")
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", nargs="*",
                    default=["first_fit", "best_fit", "fair_share"])
    ap.add_argument("--out", default=None, help="JSON report path")
    args = ap.parse_args(argv)

    report = sweep(args.policies, args.scenarios, args.ops, args.devices,
                   seed0=args.seed)
    for policy, row in report["policies"].items():
        p50, p95 = row["reconf_p50_ms"], row["reconf_p95_ms"]
        lat = (f"reconf p50={p50:.2f}ms p95={p95:.2f}ms"
               if p50 is not None else "no reconfs")
        print(f"{policy:12s} {row['ops_per_sec']:8.1f} ops/s  {lat}  "
              f"({row['reconfs']} reconfs, {row['rejected']} rejected)")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")
    else:
        print(json.dumps(report, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
