"""§Decode-roofline: achieved-vs-peak bandwidth for every decode kernel.

Each row is ONE kernel variant from the serve plane's decode step, timed
standalone at a serving-ish shape and reported through
``runtime.roofline.kernel_roofline``: analytic FLOPs / bytes for the
variant, measured wall time, and the achieved fractions against the
peaks the run was told to use.

  flash_decode     dense per-slot KV ring (the PR-2 layout)
  paged_fp         block-table-indirected fp32 page pool
  paged_int8       the same pool int8-quantized with per-(row,head) fp32
                   scales — the analytic bytes drop ~2x (gated at <=0.6x)
  fused_sample     temperature/top-k Gumbel sampling over (B, V) logits
                   (kernels/sampling.py; logits never leave the device)
  ssm_scan         the chunked SSD recurrent-path scan

Peaks are config-injectable (``Peaks`` dataclass): by default this
MEASURES the host's copy bandwidth / matmul FLOP rate
(``measure_local_peaks``) so achieved_bw_frac is a fraction of what the
backend the benchmark actually ran on can do — CPU CI numbers are not
fractions of a TPU datasheet. ``--peak-bw-gbps`` / ``--peak-tflops``
override both (e.g. pin real TPU v5e numbers on hardware).

Gates (deterministic — analytic byte ratios and row presence, plus one
generous wall-clock ratio; strict acceptance numbers live in the
committed BENCH_decode_roofline.json):
  * all five rows present with wall_s > 0
  * paged_int8 analytic bytes <= 0.6x paged_fp analytic bytes
  * paged_int8 wall <= 2.5x paged_fp wall (int8 must not give back the
    byte savings in dequant overhead)
"""
import argparse
import json
import sys
import time


def timed_best(fn, reps=5):
    fn()                                    # warm (compile)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench(reduced=False, reps=5, peaks=None):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.ref import kv_quant_ref
    from repro.runtime.roofline import (Peaks, kernel_roofline,
                                        measure_local_peaks)

    if peaks is None:
        peaks = measure_local_peaks()

    # serving-ish decode shapes (reduced on PRs: same rows, smaller walls)
    B, H, K, hd = (8, 8, 4, 64) if reduced else (32, 8, 4, 64)
    page, NP = 32, (4 if reduced else 16)   # NP*page logical tokens/seq
    T = NP * page
    V = 1024 if reduced else 4096
    S, N = (64, 32) if reduced else (256, 64)
    f32 = 4

    ks = jax.random.split(jax.random.key(0), 8)
    rows = []

    def row(name, fn, flops, bytes_moved):
        wall = timed_best(fn, reps=reps)
        r = kernel_roofline(name, flops=flops, bytes_moved=bytes_moved,
                            wall_s=wall, peaks=peaks)
        rows.append(r)
        print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                          for k, v in r.items()}))
        return r

    # -- flash_decode: dense (B, T, K, hd) KV ring -------------------------
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, K, hd), jnp.float32)
    attn_flops = 4.0 * B * H * T * hd       # qk^T + pv, 2 FLOP/MAC each
    qo_bytes = 2.0 * B * H * hd * f32       # q read + o write
    dense_bytes = 2.0 * B * T * K * hd * f32 + qo_bytes
    row("flash_decode",
        lambda: ops.flash_decode(q, k, v, T - 1).block_until_ready(),
        attn_flops, dense_bytes)

    # -- paged fp32: pool of B*NP pages + garbage page 0 -------------------
    P = 1 + B * NP
    kp = jax.random.normal(ks[3], (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(ks[4], (P, page, K, hd), jnp.float32)
    tables = (1 + jnp.arange(B * NP, dtype=jnp.int32)).reshape(B, NP)
    pos = jnp.full((B,), T - 1, jnp.int32)
    paged_bytes = (2.0 * B * NP * page * K * hd * f32    # k+v pages read
                   + B * NP * 4 + qo_bytes)              # tables + q/o
    fp = row("paged_fp",
             lambda: ops.paged_decode(q, kp, vp, tables, pos)
             .block_until_ready(),
             attn_flops, paged_bytes)

    # -- paged int8: same pool quantized, per-(row,head) fp32 scales -------
    kq, ksc = kv_quant_ref(kp)
    vq, vsc = kv_quant_ref(vp)
    int8_bytes = (2.0 * B * NP * page * K * hd * 1       # int8 payload
                  + 2.0 * B * NP * page * K * f32        # scales
                  + B * NP * 4 + qo_bytes)
    dequant_flops = 2.0 * B * T * K * hd * 2             # k and v scaling
    q8 = row("paged_int8",
             lambda: ops.paged_decode_quant(q, kq, vq, ksc, vsc, tables,
                                            pos).block_until_ready(),
             attn_flops + dequant_flops, int8_bytes)

    # -- fused sampling: (B, V) logits -> (B,) tokens on device ------------
    logits = jax.random.normal(ks[5], (B, V), jnp.float32)
    temp = jnp.where(jnp.arange(B) % 2 == 0, 0.0, 0.8).astype(jnp.float32)
    topk = jnp.where(jnp.arange(B) % 2 == 0, 0, 40).astype(jnp.int32)
    keys = jnp.stack([jnp.full((B,), 7, jnp.int32),
                      jnp.arange(B, dtype=jnp.int32),
                      jnp.zeros((B,), jnp.int32)], axis=1)
    row("fused_sample",
        lambda: ops.fused_sample(logits, temp, topk, keys,
                                 vocab_size=V).block_until_ready(),
        12.0 * B * V,                       # mask+scale+gumbel+argmax
        B * V * f32 + B * 4)

    # -- ssm_scan: the recurrent path's chunked SSD scan -------------------
    xdt = jax.random.normal(ks[6], (B, S, H, hd), jnp.float32)
    Bv = jax.random.normal(ks[7], (B, S, N), jnp.float32)
    Cv = jax.random.normal(ks[0], (B, S, N), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    ssm_bytes = (2.0 * B * S * H * hd * f32              # xdt read, y write
                 + 2.0 * B * S * N * f32 + B * S * H * f32)
    row("ssm_scan",
        lambda: ops.ssm_scan(xdt, Bv, Cv, la)[0].block_until_ready(),
        6.0 * B * S * H * hd * N, ssm_bytes)

    names = {r["name"] for r in rows}
    summary = {
        "name": "summary", "reduced": reduced,
        "int8_bytes_vs_fp": round(q8["bytes"] / fp["bytes"], 4),
        "int8_bytes_target": 0.6,
        "int8_wall_vs_fp": round(q8["wall_s"] / fp["wall_s"], 3),
        "int8_wall_target": 2.5,
        "rows_present": len(names),
        **peaks.row(),
    }
    rows.append(summary)
    print(json.dumps(summary))
    ok = (names == {"flash_decode", "paged_fp", "paged_int8",
                    "fused_sample", "ssm_scan"}
          and all(r["wall_s"] > 0 for r in rows[:-1])
          and summary["int8_bytes_vs_fp"] <= 0.6
          and summary["int8_wall_vs_fp"] <= 2.5)
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reduced", action="store_true",
                    help="smaller shapes (PR CI); same rows and gates")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--peak-bw-gbps", type=float, default=None,
                    help="override the measured copy bandwidth peak")
    ap.add_argument("--peak-tflops", type=float, default=None,
                    help="override the measured matmul FLOP-rate peak")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    peaks = None
    if args.peak_bw_gbps or args.peak_tflops:
        from repro.runtime.roofline import Peaks, measure_local_peaks
        m = measure_local_peaks()
        peaks = Peaks(
            flops=(args.peak_tflops * 1e12 if args.peak_tflops
                   else m.flops),
            hbm_bw=(args.peak_bw_gbps * 1e9 if args.peak_bw_gbps
                    else m.hbm_bw))

    rows, ok = bench(reduced=args.reduced, reps=args.reps, peaks=peaks)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
