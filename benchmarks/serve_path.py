"""§Serve: the serve-plane hot path under fleet-level concurrency.

Rows (see EXPERIMENTS.md §Serve for the protocol):

  dense_ring_16        the PR-2 baseline layout: per-slot dense KV ring of
                       ``max_len`` rows; decode walks (and the scatter
                       rewrites) the whole ``slots x max_len`` allocation
                       every step
  paged_16             block-granular paged KV (serve/paged.py): the pool
                       is sized to the tokens actually in flight, decode is
                       block-table-indirected and bucketed to the pages
                       written so far — the acceptance gate is >= 2x
                       tokens/s over dense_ring at 16+ concurrent requests
  paged_16_chunked     + chunked prefill (admission interleaves with the
                       running batch's decode instead of stalling it —
                       shows up as a lower TTFT tail, p95)
  paged_32             the same paged engine at 32-way concurrency with
                       HOST sampling — throughput reference and the I10
                       bit-identity oracle for the fused rows
  paged_fused_32       + temperature/top-k Gumbel sampling fused into the
                       device decode step (kernels/sampling.py): logits
                       never leave the device; token streams must be
                       bit-identical to paged_32
  paged_fused_int8_32  + int8-quantized paged KV (kv_dtype='int8'): ~2x
                       smaller pages; gate is >= 1.5x tokens/s over
                       paged_16, bit-identical to a host-sampled int8 twin
  paged_live_pause     the paged engine serving THROUGH a mid-run
                       ``pause_live`` + unpause (fleet/EngineTenant under
                       the real SVFFManager): p95 inter-token latency must
                       stay within 2x of the steady-state p95

Latency metrics per row: tokens/s, TTFT p50/p95 (submit -> first token),
inter-token latency p50/p95 (consecutive token walls within one request).
"""
import argparse
import json
import statistics
import sys
import time

# paged_16 tokens/s from the BENCH_serve_path.json committed in PR 4 —
# the pinned denominator for the fused+int8 acceptance gate (>= 1.5x)
PAGED16_BASELINE = 1522.35


def pct(xs, q):
    # ceil-based nearest-rank, matching serve/telemetry.percentile (the
    # old round(q*(n-1)) drifted a rank off the definition on .5 ties)
    import math
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
    return xs[i]


def make_requests(n, vocab, seed=0, max_new=24, sampled=False):
    """With ``sampled``, odd rids draw temperature/top-k Gumbel samples
    (exercising the full sampler, fused or host) and even rids stay
    greedy — the mix every 32-way row uses so fused-vs-host bit-identity
    covers both paths."""
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, int(rng.integers(6, 14))),
                    max_new_tokens=max_new,
                    temperature=0.8 if sampled and i % 2 else 0.0,
                    top_k=40 if sampled and i % 2 else 0,
                    seed=1000 + i)
            for i in range(n)]


def latencies(reqs):
    ttft, itl = [], []
    for r in reqs:
        if r.t_tok:
            ttft.append(r.t_tok[0] - r.t_submit)
            itl.extend(b - a for a, b in zip(r.t_tok, r.t_tok[1:]))
    return ttft, itl


def warm_requests(vocab):
    """One request per prompt length in the workload's range (compiles
    every prefill executable) plus one long-decode request that crosses a
    page boundary (compiles the wider block-table decode variant), so the
    timed run hits no mid-flight compiles."""
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(999)
    reqs = [Request(rid=10_000 + L, prompt=rng.integers(0, vocab, L),
                    max_new_tokens=4) for L in range(6, 14)]
    reqs.append(Request(rid=10_100, prompt=rng.integers(0, vocab, 13),
                        max_new_tokens=52))
    return reqs


def run_engine(run, params, reqs, **kw):
    from repro.serve import ServeEngine
    eng = ServeEngine(run, params, **kw)
    # warm the executables so compile time doesn't pollute latency tails
    for r in warm_requests(run.model.vocab_size):
        eng.submit(r)
    eng.run_until_idle()
    t0 = time.perf_counter()
    for r in reqs:
        r.t_submit = time.perf_counter()
        eng.queue.append(r)
    res = eng.run_until_idle()
    wall = time.perf_counter() - t0
    assert res.drained and all(r.done for r in reqs)
    return wall


def run_fleet(run, params, reqs, *, slots, max_len, page_size,
              pause: bool, pause_after_frac=0.3):
    """One paged engine as a tenant under the real manager; with ``pause``
    a pause_live (pre-copy rounds serve traffic) + unpause fires mid-run.
    The no-pause variant is the steady-state baseline for the p95
    inter-token comparison (same fleet loop, same overheads)."""
    import tempfile
    from repro.serve import ServeFleet
    fleet = ServeFleet(run, params, num_engines=1, num_devices=2,
                       slots=slots, max_len=max_len, paged=True,
                       page_size=page_size,
                       workdir=tempfile.mkdtemp(prefix="svff_bench_"))
    tn = fleet.tenants["serve0"]
    for r in warm_requests(run.model.vocab_size):
        fleet.submit(r)
    fleet.drain()
    total = sum(r.max_new_tokens for r in reqs)
    fired = not pause
    t0 = time.perf_counter()
    for r in reqs:
        r.t_submit = time.perf_counter()
        tn.engine.queue.append(r)
    pause_s = 0.0
    while any(not r.done for r in reqs):
        fleet.step()
        if not fired and sum(len(r.out) for r in reqs) \
                >= pause_after_frac * total:
            fired = True
            tp = fleet.pause_live("serve0", rounds=2)
            fleet.unpause("serve0")
            pause_s = tp.stop_s
    wall = time.perf_counter() - t0
    assert fired, "pause_live never fired"
    return wall, pause_s


def bench(requests=32, slots=16, max_len=1024, page_size=32, max_new=24,
          repeats=1):
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model

    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    vocab = run.model.vocab_size
    rows = []

    def record(name, wall, reqs, note="", extra=None):
        toks = sum(len(r.out) for r in reqs)
        ttft, itl = latencies(reqs)
        row = {"name": name, "requests": len(reqs),
               "generated_tokens": toks, "wall_s": round(wall, 4),
               "tokens_per_s": round(toks / wall, 2),
               "ttft_p50_ms": round(pct(ttft, 0.5) * 1e3, 3),
               "ttft_p95_ms": round(pct(ttft, 0.95) * 1e3, 3),
               "itl_p50_ms": round(pct(itl, 0.5) * 1e3, 3),
               "itl_p95_ms": round(pct(itl, 0.95) * 1e3, 3),
               "note": note}
        row.update(extra or {})
        rows.append(row)
        print(json.dumps(row))
        return row

    # pool sized to the in-flight tokens, not the worst case
    import math
    pages_per_req = math.ceil((14 + max_new) / page_size) + 1
    num_pages = 1 + slots * pages_per_req

    best = {}
    for name, kw in (
            ("dense_ring_16", dict(paged=False)),
            ("paged_16", dict(paged=True, page_size=page_size,
                              num_pages=num_pages)),
            ("paged_16_chunked", dict(paged=True, page_size=page_size,
                                      num_pages=num_pages,
                                      prefill_chunk=8))):
        walls = []
        for rep in range(repeats):
            reqs = make_requests(requests, vocab, seed=rep,
                                 max_new=max_new)
            wall = run_engine(run, params, reqs, slots=slots,
                              max_len=max_len, **kw)
            walls.append((wall, reqs))
        wall, reqs = min(walls, key=lambda t: t[0])
        best[name] = record(
            name, wall, reqs,
            note=(f"slots={slots} max_len={max_len} " +
                  ("page={} pool={}p".format(page_size, num_pages)
                   if kw.get("paged") else "dense ring")))

    # the acceptance gate compares the full tentpole engine (paged KV +
    # chunked-prefill admission) against the dense-ring baseline; the
    # paged_16 row isolates the cache-layout half of the win
    speedup = (best["paged_16_chunked"]["tokens_per_s"]
               / best["dense_ring_16"]["tokens_per_s"])
    layout_speedup = (best["paged_16"]["tokens_per_s"]
                      / best["dense_ring_16"]["tokens_per_s"])
    itl_speedup = (best["dense_ring_16"]["itl_p50_ms"]
                   / max(best["paged_16"]["itl_p50_ms"], 1e-9))

    # -- 32-way rows: fused device sampling + int8 paged KV (the PR-8
    # tentpole) at doubled concurrency. The host-sampled paged_32 row is
    # both the throughput reference at this width and the bit-identity
    # oracle (I10) for the fused fp row; the fused int8 row's oracle is a
    # host-sampled int8 twin (same quantized KV, host RNG). Each row
    # carries a first-order roofline: analytic decode FLOPs/bytes against
    # the HOST-measured copy/matmul peaks, so achieved_bw_frac is
    # meaningful on whatever backend CI ran on.
    import dataclasses

    import jax.tree_util as jtu
    from repro.runtime.roofline import kernel_roofline, measure_local_peaks
    from repro.serve.paged import init_paged_cache

    peaks = measure_local_peaks()
    wide = 2 * slots
    wide_pages = 1 + wide * pages_per_req
    n_active = run.model.active_param_count()
    params_bytes = sum(x.nbytes for x in jtu.tree_leaves(params))
    # mean decode context: mean prompt (uniform 6..13) + half the decode
    mean_ctx = 9.5 + (max_new + 1) / 2
    pages_touched = math.ceil(mean_ctx / page_size)

    def kv_bytes_per_page(kv_dtype):
        shape = dataclasses.replace(run.shape, seq_len=max_len,
                                    global_batch=wide)
        cache = init_paged_cache(model, shape, num_pages=2,
                                 page_size=page_size, kv_dtype=kv_dtype)
        total = 0
        for path, leaf in jtu.tree_flatten_with_path(cache)[0]:
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "xk", "xv", "k_scale", "v_scale",
                        "xk_scale", "xv_scale"):
                total += leaf.nbytes // 2          # pool has 2 pages
        return total

    wide_rows, streams0 = {}, {}
    for name, kw in (
            ("paged_32", {}),
            ("paged_fused_32", dict(fused_sampling=True)),
            ("paged_fused_int8_32", dict(fused_sampling=True,
                                         kv_dtype="int8"))):
        walls = []
        for rep in range(repeats):
            wreqs = make_requests(2 * requests, vocab, seed=100 + rep,
                                  max_new=max_new, sampled=True)
            w = run_engine(run, params, wreqs, slots=wide, max_len=max_len,
                           paged=True, page_size=page_size,
                           num_pages=wide_pages, **kw)
            walls.append((w, wreqs))
            if rep == 0:
                streams0[name] = {r.rid: list(r.out) for r in wreqs}
        w, wreqs = min(walls, key=lambda t: t[0])
        toks = sum(len(r.out) for r in wreqs)
        kvb = kv_bytes_per_page(kw.get("kv_dtype"))
        bytes_per_tok = params_bytes / wide + kvb * pages_touched
        rl = kernel_roofline(name, flops=2.0 * n_active * toks,
                             bytes_moved=bytes_per_tok * toks, wall_s=w,
                             peaks=peaks)
        wide_rows[name] = record(
            name, w, wreqs,
            note=(f"slots={wide} pool={wide_pages}p "
                  + ("fused sampling " if kw.get("fused_sampling") else "")
                  + (f"kv={kw['kv_dtype']} " if kw.get("kv_dtype") else "")
                  + "(mixed greedy/top-k requests)"),
            extra={"kv_bytes_per_page": kvb,
                   "achieved_bw_gbps": round(rl["achieved_bw"] / 1e9, 3),
                   "achieved_bw_frac": round(rl["achieved_bw_frac"], 4),
                   "roofline_bound": rl["bound"],
                   "peak_hbm_bw_gbps": round(peaks.hbm_bw / 1e9, 3)})

    oreqs = make_requests(2 * requests, vocab, seed=100, max_new=max_new,
                          sampled=True)
    run_engine(run, params, oreqs, slots=wide, max_len=max_len, paged=True,
               page_size=page_size, num_pages=wide_pages, kv_dtype="int8")
    fused_identical = streams0["paged_fused_32"] == streams0["paged_32"]
    int8_identical = (streams0["paged_fused_int8_32"]
                      == {r.rid: list(r.out) for r in oreqs})
    # -- pause_live under traffic vs the SAME fleet loop without a pause:
    # the mid-run reconfiguration's latency tax is the p95 ratio between
    # these two runs (longer run: the pause window must be amortized the
    # way real serving would, not dominate a 2-second benchmark)
    nlive = max(requests, 48)
    sreqs = make_requests(nlive, vocab, seed=11, max_new=max_new)
    swall, _ = run_fleet(run, params, sreqs, slots=slots, max_len=max_len,
                         page_size=page_size, pause=False)
    steady = record("paged_fleet_steady", swall, sreqs,
                    note="fleet loop, no reconfiguration (p95 baseline)")
    steady_p95 = steady["itl_p95_ms"]

    reqs = make_requests(nlive, vocab, seed=11, max_new=max_new)
    wall, stop_s = run_fleet(run, params, reqs, slots=slots,
                             max_len=max_len, page_size=page_size,
                             pause=True)
    live = record("paged_live_pause", wall, reqs,
                  note="pause_live(rounds=2)+unpause mid-run under "
                       "SVFFManager",
                  extra={"pause_stop_ms": round(stop_s * 1e3, 3),
                         "itl_p95_vs_steady":
                             round((pct(latencies(reqs)[1], 0.95) * 1e3)
                                   / max(steady_p95, 1e-9), 3)})

    summary = {"name": "summary",
               "paged_speedup_vs_dense": round(speedup, 3),
               "paged_layout_only_speedup": round(layout_speedup, 3),
               "paged_itl_p50_speedup": round(itl_speedup, 3),
               "speedup_target": 2.0,
               "live_pause_itl_p95_ratio": live["itl_p95_vs_steady"],
               "live_pause_itl_ratio_target": 2.0,
               "concurrency": slots,
               "wide_concurrency": wide,
               # the acceptance reference is the COMMITTED PR-4 paged_16
               # number (tokens/s), so the ratio survives this-run noise
               # and the admit-jit speedup that lifted every row; the
               # within-run ratio rides along for context
               "paged16_baseline_tokens_per_s": PAGED16_BASELINE,
               "fused_int8_speedup_vs_baseline":
                   round(wide_rows["paged_fused_int8_32"]["tokens_per_s"]
                         / PAGED16_BASELINE, 3),
               "fused_int8_speedup_vs_paged16":
                   round(wide_rows["paged_fused_int8_32"]["tokens_per_s"]
                         / best["paged_16"]["tokens_per_s"], 3),
               "fused_speedup_vs_host_32":
                   round(wide_rows["paged_fused_32"]["tokens_per_s"]
                         / wide_rows["paged_32"]["tokens_per_s"], 3),
               "fused_target": 1.5,
               "fused_bit_identical": fused_identical,
               "fused_int8_bit_identical": int8_identical}
    rows.append(summary)
    print(json.dumps(summary))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(requests=args.requests, slots=args.slots,
                 max_len=args.max_len, page_size=args.page_size,
                 max_new=args.max_new, repeats=args.repeats)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    summary = rows[-1]
    ok = (summary["paged_speedup_vs_dense"] >= 1.5
          and summary["live_pause_itl_p95_ratio"] <= 3.0
          and summary["fused_int8_speedup_vs_baseline"] >= 1.5
          and summary["fused_bit_identical"]
          and summary["fused_int8_bit_identical"])
    # generous CI floors (shared runners are noisy); the strict acceptance
    # numbers live in the committed BENCH_serve_path.json
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
