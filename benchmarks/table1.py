"""Table I reproduction: VF detach-attach vs pause-unpause overhead.

Paper setup (§V): 1 PF exposing 32 VFs; 1/4/10 VFs attached to as many
VMs; a re-configuration cycle removes/pauses all VFs and attaches/unpauses
them again; avg of 100 runs. Here: a 32-device pool (subprocess-forced CPU
devices), one tenant per VF running the svff-bench workload (~512KB state,
the paper's fast-VF-memory analogue); the cycle is Manager.reconf in both
modes. Timings are wall-clock, like the paper's ("real timings").
"""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=32")

import argparse
import json
import statistics
import sys


def bench(runs: int, vf_counts=(1, 4, 10), compression="none") -> list:
    import jax  # noqa: F401  (after XLA_FLAGS)
    from repro.configs import make_run_config
    from repro.configs.paper import PAPER_MAX_VFS
    from repro.core import DevicePool, SVFFManager, StagingEngine, Tenant

    run = make_run_config("svff-bench", "train_4k", smoke=True)
    rows = []
    for nvf in vf_counts:
        import tempfile
        wd = tempfile.mkdtemp(prefix="svff_bench_")
        pool = DevicePool(max_vfs=PAPER_MAX_VFS)
        mgr = SVFFManager(pool, workdir=wd,
                          staging=StagingEngine(compression=compression))
        tenants = [Tenant(f"vm{i}", run, local_batch=2, seq_len=16, seed=i)
                   for i in range(nvf)]
        mgr.init(num_vfs=nvf, tenants=tenants,
                 devices_per_vf=max(1, 32 // max(nvf, 1) // 2))
        for tn in tenants:
            tn.run_steps(1)               # guests live during the cycle

        samples = {"pause": [], "detach": []}
        for r in range(runs):
            for mode, use_pause in (("detach", False), ("pause", True)):
                t = mgr.reconf(num_vfs=nvf, use_pause=use_pause,
                               devices_per_vf=max(1, 32 // max(nvf, 1) // 2))
                samples[mode].append(t["total"] * 1000.0)
        d_avg = statistics.mean(samples["detach"])
        d_std = statistics.stdev(samples["detach"]) if runs > 1 else 0.0
        p_avg = statistics.mean(samples["pause"])
        p_std = statistics.stdev(samples["pause"]) if runs > 1 else 0.0
        rows.append({
            "num_vf": nvf, "runs": runs, "compression": compression,
            "detach_attach_ms": d_avg, "detach_attach_std": d_std,
            "pause_unpause_ms": p_avg, "pause_unpause_std": p_std,
            "overhead_pct": 100.0 * (p_avg - d_avg) / d_avg,
            "ms_per_vf_delta": (p_avg - d_avg) / nvf,
        })
        # paper-faithful transparency check: every guest still live
        for tn in tenants:
            tn.run_steps(1)
            assert tn.status == "running"
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=30)
    ap.add_argument("--vfs", type=int, nargs="*", default=[1, 4, 10])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(args.runs, tuple(args.vfs), args.compression)
    for r in rows:
        print(json.dumps(r))
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
