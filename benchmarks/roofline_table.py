"""Roofline aggregation: read results/dryrun/*.json (written by
launch/dryrun.py) into the §Roofline table — per (arch x shape x mesh):
three terms, dominant bound, MODEL_FLOPS/HLO_FLOPs, MFU at roofline."""
import argparse
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_rows(d: str = DRYRUN_DIR, mesh_filter=("single", "multi")) -> list:
    rows = []
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, fn)))
        if r.get("mesh") not in mesh_filter:
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh"), "status": r["status"],
                         "reason": r.get("reason", r.get("error", ""))})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "chips": r["chips"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "bound": rf["bound"],
            "step_s": rf["step_s"], "mfu": rf["mfu"],
            "useful_flops_frac": rf["useful_flops_frac"],
            "mem_per_dev_gib": (r["memory"]["argument_bytes"] +
                                r["memory"]["temp_bytes"]) / 2**30,
            "compile_s": r["compile_s"],
        })
    return rows


def markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "bound | step_s | useful | MFU | mem/dev GiB |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | {r['status']} |  |  |  |  |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['bound']}** "
            f"| {r['step_s']:.4f} | {r['useful_flops_frac']:.2f} "
            f"| {r['mfu']*100:.1f}% | {r['mem_per_dev_gib']:.2f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DRYRUN_DIR)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load_rows(args.dir)
    if args.markdown:
        print(markdown(rows))
    else:
        for r in rows:
            print(json.dumps(r))
    return 0


if __name__ == "__main__":
    sys.exit(main())
