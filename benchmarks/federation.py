"""§Federation: lease-routed multi-host fleet at scale — exactly-once
admission under network faults, deterministic token streams without them.

The claims under test (see EXPERIMENTS.md §Federation):

  1. exactly-once serving (I15) — across every run, including the
     partition run, no request id ever receives a token from more than
     one host (dual-serve ledger stays empty), and no completed request
     id appears in more than one engine's completion table;
  2. determinism — two full runs at ``partition_rate=0`` with the same
     seed produce BIT-IDENTICAL fleet token digests (crc32 over the
     sorted ``(rid, token stream)`` completion set);
  3. completion — every request the coordinator successfully admitted
     (including admissions whose ack was lost and later confirmed by
     ``reconcile``) completes exactly once; nothing is lost, nothing is
     re-served;
  4. scale — the committed artifact covers >= 8 hosts x 256 lite
     engines each and >= 1e5 simulated requests, with throughput
     (admissions/s, tokens/s) reported as context.

Protocol: three runs on a fleet of ``Host``s whose serve plane is
``LiteEngine``s — dict-backed engines exposing exactly the duck-typed
surface ``Host.submit``/``serve_targets`` route on (``submit_request``,
``queue``, ``active``, ``SLOTS``, ``owns_request``), with counter-hashed
token streams that depend only on ``(rid, run seed)`` —

  base      partition_rate=0: the full request count, drained to empty
  rerun     the SAME config again; its digest must equal base's
  faults    partition_rate>0: armed ack-loss windows (admit lands, ack
            dies -> in-doubt -> heal -> ``reconcile`` confirms), random
            coordinator<->host partitions long enough to lapse leases,
            and one mid-run coordinator ``handoff`` (epoch fence)

All time is a ``VirtualClock``; one tick = one synchronized decode step
across every engine on every host (partitioned hosts keep stepping —
the partition cuts the control plane, not host-local progress).

Acceptance gates (committed BENCH_federation.json):
  * dual-serve violations == 0 over ALL runs (ledger + completion-table
    uniqueness);
  * base digest == rerun digest (bit-identity at partition_rate=0);
  * every run: completed rid set == admitted rid set, 0 lost;
  * faults run: >= 1 in-doubt admission confirmed, >= 1 partition,
    epoch advanced past the handoff.
CI reruns a reduced fleet on PRs with the same gates (minus the scale
floor, which only the committed full artifact must meet).
"""
import argparse
import json
import os
import random
import shutil
import sys
import tempfile
import time
import zlib

VOCAB = 257
TICK_S = 0.5             # virtual seconds per fleet step
PARTITION_TICKS = 8      # long enough to lapse a 3.0 s lease at TICK_S


class DualServeLedger:
    """I15 witness: the first host to emit a token for a rid owns it
    forever; any token from a different host is a violation."""

    def __init__(self):
        self.owner = {}
        self.violations = []

    def record(self, rid, host_id):
        prev = self.owner.setdefault(rid, host_id)
        if prev != host_id:
            self.violations.append(
                {"rid": rid, "first": prev, "second": host_id})


class LiteEngine:
    """Minimal routable engine: the duck-typed serve surface ``Host``
    consumes, nothing else. Token streams are counter hashes of
    ``(rid, position, seed)`` so they depend only on the request, never
    on placement — bit-identity across runs is a property of routing
    determinism, which is exactly what the bench measures."""

    SLOTS = 4

    def __init__(self, tid, host_id, ledger):
        self.tid = tid
        self.host_id = host_id
        self.ledger = ledger
        self.queue = []
        self.active = [None] * self.SLOTS
        self.done = {}           # rid -> tuple(token stream)

    def submit_request(self, rid, seed=None):
        seed = 0 if seed is None else seed
        req = {"rid": rid, "seed": seed, "tokens": [],
               "max_new": 1 + zlib.crc32(b"%d:%d" % (rid, seed)) % 4}
        self.queue.append(req)
        return req

    def owns_request(self, rid):
        return (any(r is not None and r["rid"] == rid for r in self.active)
                or any(r["rid"] == rid for r in self.queue))

    def step(self):
        emitted = 0
        for i in range(self.SLOTS):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.pop(0)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = zlib.crc32(b"%d:%d:%d" % (r["rid"], len(r["tokens"]),
                                            r["seed"])) % VOCAB
            r["tokens"].append(tok)
            self.ledger.record(r["rid"], self.host_id)
            emitted += 1
            if len(r["tokens"]) >= r["max_new"]:
                self.done[r["rid"]] = tuple(r["tokens"])
                self.active[i] = None
        return emitted


def make_fleet(workdir, *, hosts, engines_per_host, policy, ledger):
    from repro.core import FederationCoordinator, Host
    from repro.sim import VirtualClock
    clock = VirtualClock()
    fleet = []
    for h in range(hosts):
        hid = f"h{h}"
        host = Host(hid, workdir=os.path.join(workdir, hid), clock=clock,
                    num_devices=2, max_vfs=2, policy=policy,
                    max_load_per_engine=LiteEngine.SLOTS + 2)
        for e in range(engines_per_host):
            tid = f"{hid}.e{e:04d}"
            host.engines[tid] = LiteEngine(tid, hid, ledger)
        fleet.append(host)
    co = FederationCoordinator(fleet, clock=clock, policy=policy)
    co.heartbeat_all()
    return clock, fleet, co


def fleet_digest(fleet):
    """crc32 over the sorted (rid, token stream) completion set — the
    bit-identity witness. Also returns the completed rid list and the
    count of rids completed by more than one engine (must be 0)."""
    rows, dup = [], 0
    seen = set()
    for host in fleet:
        for eng in host.engines.values():
            for rid, toks in eng.done.items():
                if rid in seen:
                    dup += 1
                seen.add(rid)
                rows.append((rid, toks))
    rows.sort()
    d = 0
    for rid, toks in rows:
        d = zlib.crc32(repr((rid, toks)).encode(), d)
    return d, seen, dup


def run_once(label, *, hosts, engines_per_host, requests, policy, seed,
             partition_rate, handoff_at=None):
    """Drive one federation run to full drain; returns the report row."""
    from repro.core import AdmissionError, HostUnreachableError
    ledger = DualServeLedger()
    workdir = tempfile.mkdtemp(prefix="svff_bench_fed_")
    t0 = time.perf_counter()
    try:
        clock, fleet, co = make_fleet(
            workdir, hosts=hosts, engines_per_host=engines_per_host,
            policy=policy, ledger=ledger)
        rng = random.Random(seed)
        rate = hosts * engines_per_host * LiteEngine.SLOTS // 3
        admitted, in_doubt_confirmed, lost = set(), 0, 0
        tokens = ticks = reroute_ticks = 0
        part_until, partitions = -1, 0
        # a fault run always exercises BOTH catalogued shapes at fixed
        # ticks (ack loss, lease-lapsing partition); the random rate
        # rides on top — keeps the gates deterministic per seed
        forced = {2: "ack", 6: "part"} if partition_rate > 0 else {}
        while len(admitted) < requests or any(
                h.load() for h in fleet):
            ticks += 1
            if ticks == part_until:
                co.fabric.heal()
                co.heartbeat_all()
                rec = co.reconcile()
                in_doubt_confirmed += len(rec["confirmed"])
                lost += len(rec["lost"])
            co.heartbeat_all()
            if (handoff_at is not None and len(admitted) >= handoff_at
                    and co.epoch == 1):
                co = co.handoff()
            fault = None
            if not co.fabric.partitioned and partition_rate > 0:
                if ticks in forced:
                    fault = forced.pop(ticks)
                elif rng.random() < partition_rate:
                    fault = "ack" if rng.random() < 0.5 else "part"
            if fault == "ack":
                # ack loss: the NEXT admission lands, its ack dies
                co.fabric.arm("fed_submit_after_admit", [co.node_id])
            elif fault == "part":
                # hard partition: one host drops off the control plane
                # long enough for its lease to lapse
                victim = f"h{rng.randrange(hosts)}"
                co.fabric.partition(
                    [n for n in [co.node_id] + sorted(co.hosts)
                     if n != victim])
                partitions += 1
                part_until = ticks + PARTITION_TICKS
            for _ in range(rate):
                if len(admitted) >= requests:
                    break
                try:
                    res = co.submit(seed=seed)
                except (AdmissionError, HostUnreachableError):
                    reroute_ticks += 1
                    break          # fleet full or cut off: drain a tick
                admitted.add(res["rid"])
                if res["in_doubt"]:
                    co.fabric.heal()
                    co.heartbeat_all()
                    rec = co.reconcile()
                    in_doubt_confirmed += len(rec["confirmed"])
                    lost += len(rec["lost"])
            for host in fleet:
                for eng in host.engines.values():
                    tokens += eng.step()
            clock.advance(TICK_S)
        digest, completed, dup = fleet_digest(fleet)
        wall = time.perf_counter() - t0
        return {
            "run": label, "hosts": hosts,
            "engines": hosts * engines_per_host,
            "policy": policy, "seed": seed,
            "partition_rate": partition_rate,
            "requests": requests,
            "admitted": len(admitted), "completed": len(completed),
            "complete_ok": completed == admitted and lost == 0,
            "dual_serve_violations": len(ledger.violations) + dup,
            "tokens": tokens, "ticks": ticks,
            "digest": digest,
            "in_doubt_confirmed": in_doubt_confirmed, "lost": lost,
            "partitions": partitions, "fabric_partitions": co.fabric.partitions,
            "epoch": co.epoch,
            "coordinator_rejections": co.rejections,
            "reroute_ticks": reroute_ticks,
            "wall_s": round(wall, 3),
            "admits_per_s": round(len(admitted) / wall, 1),
            "tokens_per_s": round(tokens / wall, 1),
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def bench(*, hosts, engines_per_host, requests, policy, seed,
          partition_rate, reduced):
    rows = []
    base = run_once("base", hosts=hosts, engines_per_host=engines_per_host,
                    requests=requests, policy=policy, seed=seed,
                    partition_rate=0.0)
    rows.append(base)
    print(json.dumps(base), flush=True)
    rerun = run_once("rerun", hosts=hosts,
                     engines_per_host=engines_per_host,
                     requests=requests, policy=policy, seed=seed,
                     partition_rate=0.0)
    rows.append(rerun)
    print(json.dumps(rerun), flush=True)
    fault_requests = max(requests // 6, 2000)
    faults = run_once("faults", hosts=hosts,
                      engines_per_host=engines_per_host,
                      requests=fault_requests, policy=policy, seed=seed,
                      partition_rate=partition_rate,
                      handoff_at=fault_requests // 2)
    rows.append(faults)
    print(json.dumps(faults), flush=True)

    gates = {
        "dual_serve_zero": all(r["dual_serve_violations"] == 0
                               for r in rows),
        "digest_identical": base["digest"] == rerun["digest"],
        "complete_exactly_once": all(r["complete_ok"] for r in rows),
        "faults_exercised": (faults["in_doubt_confirmed"] >= 1
                             and faults["partitions"] >= 1
                             and faults["epoch"] >= 2),
    }
    scale = {"hosts_ok": hosts >= 8,
             "requests_ok": base["requests"] >= 100_000}
    summary = {
        "run": "summary", "reduced": reduced,
        "gates": gates, "scale": scale,
        "all_gates": all(gates.values()) and (
            reduced or all(scale.values())),
    }
    rows.append(summary)
    print(json.dumps(summary), flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=8)
    ap.add_argument("--engines-per-host", type=int, default=256)
    ap.add_argument("--requests", type=int, default=120_000)
    ap.add_argument("--policy", default="fair_share")
    ap.add_argument("--partition-rate", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="PR-sized fleet: 3 hosts x 16 engines, 3k "
                         "requests, same gates minus the scale floor")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.reduced:
        args.hosts = min(args.hosts, 3)
        args.engines_per_host = min(args.engines_per_host, 16)
        args.requests = min(args.requests, 3_000)
    rows = bench(hosts=args.hosts, engines_per_host=args.engines_per_host,
                 requests=args.requests, policy=args.policy,
                 seed=args.seed, partition_rate=args.partition_rate,
                 reduced=args.reduced)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if rows[-1]["all_gates"] else 1


if __name__ == "__main__":
    sys.exit(main())
