"""Table II reproduction: per-macro-step timings of one reconfiguration
cycle (rescan / remove VF / change #VF / add VF), detach-attach vs
pause-unpause, for 1/4/10 VFs — a single representative run, like the
paper's ("these timings represent one particular run")."""
import os
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=32")

import argparse
import json
import sys

STEPS = ("rescan", "remove_vf", "change_num_vf", "add_vf", "total")


def bench(vf_counts=(1, 4, 10), warmup: int = 2) -> list:
    import jax  # noqa: F401
    from repro.configs import make_run_config
    from repro.configs.paper import PAPER_MAX_VFS
    from repro.core import DevicePool, SVFFManager, Tenant

    run = make_run_config("svff-bench", "train_4k", smoke=True)
    rows = []
    for nvf in vf_counts:
        import tempfile
        wd = tempfile.mkdtemp(prefix="svff_t2_")
        pool = DevicePool(max_vfs=PAPER_MAX_VFS)
        mgr = SVFFManager(pool, workdir=wd)
        tenants = [Tenant(f"vm{i}", run, local_batch=2, seq_len=16, seed=i)
                   for i in range(nvf)]
        per = max(1, 32 // max(nvf, 1) // 2)
        mgr.init(num_vfs=nvf, tenants=tenants, devices_per_vf=per)
        for _ in range(warmup):           # steady-state, like the paper
            mgr.reconf(num_vfs=nvf, use_pause=True, devices_per_vf=per)
            mgr.reconf(num_vfs=nvf, use_pause=False, devices_per_vf=per)
        da = mgr.reconf(num_vfs=nvf, use_pause=False, devices_per_vf=per)
        pu = mgr.reconf(num_vfs=nvf, use_pause=True, devices_per_vf=per)
        row = {"num_vf": nvf}
        for s in STEPS:
            row[f"DA_{s}_ms"] = da[s] * 1000.0
            row[f"PU_{s}_ms"] = pu[s] * 1000.0
        rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vfs", type=int, nargs="*", default=[1, 4, 10])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = bench(tuple(args.vfs))
    for r in rows:
        print(json.dumps(r))
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
